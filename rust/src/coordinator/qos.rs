//! Weighted fair-share QoS: the per-tenant credit/virtual-time
//! primitives behind admission and dequeue under
//! [`super::QosPolicy::FairShare`].
//!
//! The service's capacity is a shared resource; before this module it
//! was allocated FIFO — whoever submitted first owned the queues, and
//! one greedy tenant could starve every other (the paper's kernel
//! keeps the vector pipeline saturated, but saturation is worthless
//! if it is all one tenant's backlog). Fair-share QoS splits the
//! mechanism into two classic pieces, both costed in **bytes**
//! rather than jobs (a 1M-element sort is not the same bite of the
//! machine as a 100-element one — and now that the service accepts
//! more than one element width, a 500K-element `u64` sort is the
//! same bite as a 1M-element `u32` one; byte denomination is what
//! keeps the shares comparable across widths):
//!
//! * **Start-time fair queueing (SFQ) dequeue.** Every enqueued job
//!   carries a virtual-time tag: `tag = max(tenant_vtime, global_v) +
//!   cost·SCALE/weight`, where `global_v` tracks the largest tag ever
//!   dequeued. Shards pop the *lowest tag* instead of the head, so a
//!   weight-2 tenant's tags advance half as fast per byte and it
//!   drains twice the bytes per unit of contention. The
//!   `max(…, global_v)` term is the no-banking rule: a tenant that
//!   idles does not accumulate credit it can later dump as a burst —
//!   it re-enters at the current virtual time.
//!
//! * **Over-share shedding at admission.** Each tenant's in-flight
//!   cost (admitted, not yet completed/cancelled) is tracked; the
//!   amount beyond its [`ClientConfig::burst`] allowance, normalized
//!   by weight, is its *over-share measure*. Admission stays
//!   work-conserving — while any shard has room, everyone gets in —
//!   but when every shard is full the most-over-share tenant loses:
//!   either the arriving request is shed
//!   ([`super::BusyReason::OverShare`], when the arrival itself is
//!   the worst offender) or the worst offender's newest queued job is
//!   **evicted** to make room for a less-loaded arrival. That is the
//!   difference from FIFO backpressure, which always sheds whoever
//!   arrived last — i.e. punishes the victim of the overload rather
//!   than its source.
//!
//! The arithmetic lives here as small pure functions
//! ([`QosState::charge`], [`QosState::over_share`], [`pick_victim`])
//! so the scheduling math is unit-testable without threads; the
//! queues, locks, and eviction scan live in `service.rs`.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::time::Duration;

/// Fixed-point scale for virtual time: one byte of cost advances a
/// weight-1 tenant's clock by `VT_SCALE` ticks, a weight-`w` tenant's
/// by `VT_SCALE / w` — integer math with enough headroom that weights
/// up to `VT_SCALE` still resolve distinctly.
pub(super) const VT_SCALE: u64 = 1 << 10;

/// Floor on a request's admission cost, in **bytes**. The shard
/// queues are bounded in *job slots* as well as memory, and a slot
/// costs control plane (admission, dequeue scan, completion
/// signaling) regardless of payload — without a floor, a flood of
/// tiny requests could occupy every slot while its literal byte count
/// stayed under any reasonable burst, evading the over-share
/// machinery entirely (job-count exhaustion instead of byte
/// exhaustion). Flooring each job at roughly a fuse-sized tiny
/// request's bytes (256 `u32` elements = 1 KiB) closes that: at the
/// default `queue_capacity` (1024) a slot-hogging flood reaches the
/// default 128 KiB burst after ~128 queued jobs. The floor also feeds
/// the virtual-time tags, so slot hogs are deranked by dequeue as
/// well as policed by admission.
pub(super) const MIN_JOB_COST: u64 = 1024;

/// A request's admission cost: its payload size in bytes
/// (`ElemBuf::byte_len` — element count × element width), floored at
/// [`MIN_JOB_COST`] (see there). This is the unit the in-flight
/// gauge, `burst`, and the virtual clock are all denominated in;
/// bytes rather than elements, so a tenant cannot double its
/// effective share by switching to 8-byte elements.
pub(super) fn job_cost(byte_len: usize) -> u64 {
    (byte_len as u64).max(MIN_JOB_COST)
}

/// Per-tenant QoS configuration, passed to
/// [`super::SortService::client_with`]. Plain [`super::SortService::client`]
/// uses `ClientConfig::default()` (weight 1).
///
/// * `weight` — the tenant's relative share of contended capacity:
///   under sustained pressure from multiple backlogged tenants,
///   completed **bytes** converge to the ratio of the weights.
///   `0` is treated as `1`.
/// * `burst` — in-flight payload **bytes** the tenant may hold before
///   it counts as *over its share* at all. Within the burst a tenant
///   is never shed with `OverShare` and never eviction-targeted;
///   sizing it to a few typical requests lets bursty-but-light
///   tenants ride through contention untouched. Remember the byte
///   denomination when sizing for wide elements: a `u64` or
///   key–payload request consumes its burst at 8 bytes per element,
///   twice the `u32` rate.
/// * `default_deadline` — when set, every submit from this tenant
///   carries a deadline of *now + default_deadline* unless the
///   per-call [`super::SortClient::submit_with_deadline`] overrides
///   it. A job whose deadline expires while still queued is reaped
///   (handle resolves [`super::SortError::DeadlineExceeded`], QoS
///   charge refunded). `None` (the default) means no deadline.
///
/// # Examples
///
/// ```
/// use neonms::coordinator::{ClientConfig, SortService};
///
/// let svc = SortService::start_default().unwrap();
/// // A paying tenant gets 4× the contended share of a default one.
/// let gold = svc.client_with("gold", ClientConfig { weight: 4, ..Default::default() });
/// let free = svc.client("free"); // ClientConfig::default(): weight 1
/// assert_eq!(gold.config().weight, 4);
/// assert_eq!(free.config().weight, 1);
///
/// // The share gauge reports each tenant's fair fraction.
/// let snap = svc.metrics();
/// assert_eq!(snap.tenants[0].name, "free");
/// assert!((snap.tenants[0].share - 0.2).abs() < 1e-9);
/// assert!((snap.tenants[1].share - 0.8).abs() < 1e-9);
/// svc.shutdown();
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClientConfig {
    /// Relative fair-share weight (≥ 1; `0` is clamped to `1`).
    pub weight: u32,
    /// In-flight admission-cost allowance before the tenant is
    /// considered over its share at all (the over-share measure
    /// admission compares under pressure is
    /// `(in_flight − burst) / weight`, floored at zero). Denominated
    /// in **bytes**, with each job's cost floored at 1 KiB — so the
    /// default 131072 covers either ~128 KiB of payload (32K `u32`
    /// or 16K `u64`/pair elements) or ~128 queued requests, whichever
    /// a tenant's traffic hits first.
    pub burst: usize,
    /// Deadline applied to every submit that does not carry its own
    /// (see [`super::SortClient::submit_with_deadline`]). Expired
    /// jobs are lazily reaped at dequeue with their QoS charge
    /// refunded. `None` disables per-tenant deadlines.
    pub default_deadline: Option<Duration>,
}

impl Default for ClientConfig {
    fn default() -> Self {
        // 128 KiB ≈ a handful of fuse-cutoff-sized requests at either
        // element width: enough that small interactive tenants never
        // trip the over-share machinery, small enough that a flood
        // does.
        ClientConfig { weight: 1, burst: 128 * 1024, default_deadline: None }
    }
}

/// `Option<Duration>` packed into one atomic for [`QosState`]:
/// `u64::MAX` is `None`, anything else is nanoseconds (saturating —
/// a ~584-year deadline and an infinite one are indistinguishable,
/// acceptably).
fn encode_deadline(d: Option<Duration>) -> u64 {
    match d {
        None => u64::MAX,
        Some(d) => d.as_nanos().min(u64::MAX as u128 - 1) as u64,
    }
}

fn decode_deadline(ns: u64) -> Option<Duration> {
    (ns != u64::MAX).then(|| Duration::from_nanos(ns))
}

/// One tenant's live QoS state: configuration plus the in-flight /
/// queued / virtual-time counters admission and dequeue trade on.
/// Embedded in [`super::metrics::TenantMetrics`] so the same atomics
/// double as the snapshot gauges.
#[derive(Debug)]
pub(super) struct QosState {
    weight: AtomicU32,
    burst: AtomicU64,
    /// Payload bytes admitted and not yet completed/cancelled/evicted.
    in_flight: AtomicU64,
    /// Jobs currently sitting in a shard queue (eviction candidates).
    queued: AtomicU64,
    /// Virtual finish time of this tenant's last enqueued job
    /// ([`VT_SCALE`] units).
    vtime: AtomicU64,
    /// [`ClientConfig::default_deadline`], packed via
    /// [`encode_deadline`]. Jobs snapshot it at admission; queued
    /// jobs keep the deadline they were admitted under.
    deadline_ns: AtomicU64,
}

impl QosState {
    pub(super) fn new(cfg: ClientConfig) -> Self {
        QosState {
            weight: AtomicU32::new(cfg.weight.max(1)),
            burst: AtomicU64::new(cfg.burst as u64),
            in_flight: AtomicU64::new(0),
            queued: AtomicU64::new(0),
            vtime: AtomicU64::new(0),
            deadline_ns: AtomicU64::new(encode_deadline(cfg.default_deadline)),
        }
    }

    /// Apply a (re)configuration — the last explicit
    /// [`super::SortService::client_with`] call wins; already-queued
    /// jobs keep the tags they were charged under.
    pub(super) fn configure(&self, cfg: ClientConfig) {
        self.weight.store(cfg.weight.max(1), Ordering::Relaxed);
        self.burst.store(cfg.burst as u64, Ordering::Relaxed);
        self.deadline_ns.store(encode_deadline(cfg.default_deadline), Ordering::Relaxed);
    }

    pub(super) fn config(&self) -> ClientConfig {
        ClientConfig {
            weight: self.weight.load(Ordering::Relaxed),
            burst: self.burst.load(Ordering::Relaxed) as usize,
            default_deadline: decode_deadline(self.deadline_ns.load(Ordering::Relaxed)),
        }
    }

    /// The tenant's current default deadline (admission snapshot).
    pub(super) fn default_deadline(&self) -> Option<Duration> {
        decode_deadline(self.deadline_ns.load(Ordering::Relaxed))
    }

    pub(super) fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Relaxed)
    }

    pub(super) fn queued(&self) -> u64 {
        self.queued.load(Ordering::Relaxed)
    }

    pub(super) fn weight(&self) -> u32 {
        self.weight.load(Ordering::Relaxed).max(1)
    }

    /// Charge an admission of `cost` bytes: bump the in-flight
    /// gauge and advance the virtual clock by `cost·SCALE/weight`
    /// from `max(vtime, global_v)` (SFQ start rule — no banked
    /// credit). Returns `(vtag, vdelta)`: the tag the queued job is
    /// ordered by, and the clock advance to hand back via
    /// [`QosState::uncharge`] if admission ultimately sheds.
    pub(super) fn charge(&self, cost: u64, global_v: &AtomicU64) -> (u64, u64) {
        let w = self.weight() as u64;
        let delta = (cost.max(1).saturating_mul(VT_SCALE) / w).max(1);
        self.in_flight.fetch_add(cost, Ordering::Relaxed);
        let gv = global_v.load(Ordering::Relaxed);
        let mut tag = 0;
        let _ = self.vtime.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            tag = v.max(gv).saturating_add(delta);
            Some(tag)
        });
        (tag, delta)
    }

    /// Roll back a [`QosState::charge`] whose admission shed: the
    /// request never entered a queue, so the tenant is not billed for
    /// it. (Approximate under interleaving — `fetch_sub` commutes —
    /// which is fine: tags already handed to queued jobs are what
    /// ordering uses, not the live clock.)
    pub(super) fn uncharge(&self, cost: u64, vdelta: u64) {
        self.in_flight.fetch_sub(cost, Ordering::Relaxed);
        self.vtime.fetch_sub(vdelta, Ordering::Relaxed);
    }

    /// Release `cost` in-flight bytes — a job finished or was
    /// cancelled. The virtual clock is *not* handed back here: served
    /// (or abandoned-after-dequeue) work is spent.
    ///
    /// **Evictions must use [`QosState::uncharge`] instead**: an
    /// evicted job consumed no service, and keeping its virtual-time
    /// charge compounds under eviction churn until the evicted
    /// tenant's tags run away and it starves — the Python mirror
    /// measured a 4:2:1 weight vector serving at ~76:3.7:1 with the
    /// charge kept, ~4:2:1 with the refund.
    pub(super) fn release(&self, cost: u64) {
        self.in_flight.fetch_sub(cost, Ordering::Relaxed);
    }

    /// A queued job entered (`+1`) a shard queue.
    pub(super) fn enqueued(&self) {
        self.queued.fetch_add(1, Ordering::Relaxed);
    }

    /// A queued job left a shard queue (popped, evicted, or drained
    /// at shutdown).
    pub(super) fn dequeued(&self) {
        self.queued.fetch_sub(1, Ordering::Relaxed);
    }

    /// The over-share measure admission compares under pressure:
    /// in-flight bytes beyond the burst allowance, normalized by
    /// weight (`VT_SCALE` fixed point). `0` means the tenant is
    /// within its allowance and can never be shed for share reasons
    /// or picked as an eviction victim.
    pub(super) fn over_share(&self) -> u64 {
        let excess = self.in_flight().saturating_sub(self.burst.load(Ordering::Relaxed));
        excess.saturating_mul(VT_SCALE) / self.weight() as u64
    }
}

/// Pick the eviction victim among `candidates` = `(over_share,
/// has_queued_work)`: the *most* over-share tenant with at least one
/// queued job, and only if it is **strictly** more over share than
/// the arrival. Returns its index. `None` means the arrival is itself
/// the worst offender (or nobody evictable exists) — then the arrival
/// is the one shed, exactly the "shed the tenant most over its share
/// first" rule.
pub(super) fn pick_victim(
    arrival_over: u64,
    candidates: impl Iterator<Item = (u64, bool)>,
) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for (i, (over, has_queued)) in candidates.enumerate() {
        if !has_queued || over <= arrival_over {
            continue;
        }
        match best {
            Some((_, b)) if over <= b => {}
            _ => best = Some((i, over)),
        }
    }
    best.map(|(i, _)| i)
}

/// The `retry_after_hint` attached to an
/// [`super::BusyReason::OverShare`] shed: roughly one median
/// queue-to-completion latency — by then some of the tenant's
/// in-flight cost will have drained. A hint, not a promise: clamped
/// to `[50 µs, 1 s]`, defaulting to 1 ms before the service has any
/// latency samples.
pub(super) fn retry_after_hint(p50_us: u64) -> Duration {
    let us = if p50_us == 0 { 1_000 } else { p50_us.clamp(50, 1_000_000) };
    Duration::from_micros(us)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(weight: u32, burst: usize) -> QosState {
        QosState::new(ClientConfig { weight, burst, ..Default::default() })
    }

    #[test]
    fn default_config_is_weight_one() {
        let cfg = ClientConfig::default();
        assert_eq!(cfg.weight, 1);
        assert!(cfg.burst > 0);
    }

    #[test]
    fn zero_weight_clamps_to_one() {
        let s = state(0, 0);
        assert_eq!(s.weight(), 1);
        s.configure(ClientConfig { weight: 0, burst: 8, ..Default::default() });
        assert_eq!(s.weight(), 1);
        assert_eq!(s.config().burst, 8);
    }

    #[test]
    fn charge_advances_vtime_inversely_to_weight() {
        let gv = AtomicU64::new(0);
        let light = state(1, 0);
        let heavy = state(4, 0);
        let (t1, d1) = light.charge(1000, &gv);
        let (t4, d4) = heavy.charge(1000, &gv);
        assert_eq!(d1, 1000 * VT_SCALE);
        assert_eq!(d4, 1000 * VT_SCALE / 4);
        assert_eq!(t1, d1);
        assert_eq!(t4, d4);
        assert!(t4 < t1, "equal cost must tag the heavier tenant earlier");
        // Tags are strictly increasing per tenant (FIFO within).
        let (t4b, _) = heavy.charge(1000, &gv);
        assert!(t4b > t4);
    }

    #[test]
    fn charge_tiny_costs_still_advance() {
        // cost 0 (empty sort) and enormous weights must still produce
        // a strictly positive delta — within-tenant FIFO depends on
        // strictly increasing tags.
        let gv = AtomicU64::new(0);
        let s = state(u32::MAX, 0);
        let (t1, d1) = s.charge(0, &gv);
        let (t2, _) = s.charge(0, &gv);
        assert!(d1 >= 1);
        assert!(t2 > t1);
    }

    #[test]
    fn idle_tenant_rejoins_at_global_virtual_time() {
        // The no-banking rule: a tenant that idles while global_v
        // advances does not return with a huge credit.
        let gv = AtomicU64::new(0);
        let busy = state(1, 0);
        let idler = state(1, 0);
        let (t, _) = busy.charge(10_000, &gv);
        gv.store(t, Ordering::Relaxed); // as the dequeue side would
        let (ti, _) = idler.charge(1, &gv);
        assert!(ti > t, "idler re-enters at current virtual time, not at zero");
    }

    #[test]
    fn uncharge_rolls_back_and_release_frees() {
        let gv = AtomicU64::new(0);
        let s = state(2, 0);
        let (_, d) = s.charge(500, &gv);
        assert_eq!(s.in_flight(), 500);
        s.uncharge(500, d);
        assert_eq!(s.in_flight(), 0);
        assert_eq!(s.vtime.load(Ordering::Relaxed), 0);
        s.charge(300, &gv);
        s.release(300);
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn over_share_respects_burst_and_weight() {
        let s = state(2, 100);
        let gv = AtomicU64::new(0);
        s.charge(100, &gv);
        assert_eq!(s.over_share(), 0, "within burst: never over share");
        s.charge(100, &gv);
        // 100 bytes beyond burst, weight 2 → 50·SCALE.
        assert_eq!(s.over_share(), 100 * VT_SCALE / 2);
        let heavy = state(4, 100);
        heavy.charge(200, &gv);
        assert!(
            heavy.over_share() < s.over_share(),
            "equal excess, higher weight → less over share"
        );
    }

    #[test]
    fn pick_victim_takes_strictly_worse_offender_with_queued_work() {
        // Victim must beat the arrival strictly and have queued work.
        assert_eq!(pick_victim(0, [(5, true), (9, true), (7, true)].into_iter()), Some(1));
        assert_eq!(
            pick_victim(0, [(5, false), (9, false)].into_iter()),
            None,
            "nothing queued → nothing evictable"
        );
        assert_eq!(
            pick_victim(9, [(5, true), (9, true)].into_iter()),
            None,
            "ties go to the arrival being shed, not an eviction"
        );
        assert_eq!(pick_victim(6, [(5, true), (9, true)].into_iter()), Some(1));
        assert_eq!(pick_victim(0, std::iter::empty()), None);
    }

    #[test]
    fn default_deadline_round_trips_through_the_packed_atomic() {
        let s = state(1, 0);
        assert_eq!(s.default_deadline(), None);
        s.configure(ClientConfig { default_deadline: Some(Duration::ZERO), ..Default::default() });
        assert_eq!(
            s.default_deadline(),
            Some(Duration::ZERO),
            "ZERO is a real (instantly expiring) deadline, not None"
        );
        s.configure(ClientConfig {
            default_deadline: Some(Duration::from_millis(5)),
            ..Default::default()
        });
        assert_eq!(s.config().default_deadline, Some(Duration::from_millis(5)));
        s.configure(ClientConfig::default());
        assert_eq!(s.default_deadline(), None);
    }

    #[test]
    fn retry_hint_is_clamped_with_a_floor_default() {
        assert_eq!(retry_after_hint(0), Duration::from_micros(1_000));
        assert_eq!(retry_after_hint(10), Duration::from_micros(50));
        assert_eq!(retry_after_hint(400), Duration::from_micros(400));
        assert_eq!(retry_after_hint(u64::MAX), Duration::from_secs(1));
    }
}
