use super::neon_ms::{NeonMergeSort, SortConfig};
use super::parallel::ParallelNeonMergeSort;
use crate::kernels::inregister::ColumnNetwork;
use crate::kernels::{MergeImpl, MergeWidth};
use crate::testutil::{assert_permutation, assert_sorted, forall, forall_indexed, Rng};

fn check_sort(sorter: &NeonMergeSort, data: &[u32], ctx: &str) {
    let mut v = data.to_vec();
    sorter.sort(&mut v);
    assert_sorted(&v, ctx);
    assert_permutation(&v, data, ctx);
}

#[test]
fn sorts_empty_and_tiny() {
    let s = NeonMergeSort::paper_default();
    for len in 0..65usize {
        let mut rng = Rng::new(len as u64);
        check_sort(&s, &rng.vec_u32(len), &format!("len {len}"));
    }
}

#[test]
fn sorts_random_sizes_around_boundaries() {
    let s = NeonMergeSort::paper_default();
    forall_indexed(80, |case, rng| {
        // Cluster sizes around powers of two and block multiples.
        let base = [63usize, 64, 65, 127, 128, 129, 1023, 1024, 4096][case % 9];
        let len = base + rng.below(5);
        check_sort(&s, &rng.vec_u32(len), &format!("len {len}"));
    });
}

#[test]
fn sorts_adversarial_patterns() {
    let s = NeonMergeSort::paper_default();
    let n = 10_000;
    let patterns: Vec<(&str, Vec<u32>)> = vec![
        ("presorted", (0..n).collect()),
        ("reverse", (0..n).rev().collect()),
        ("constant", vec![42; n as usize]),
        ("two-values", (0..n).map(|x| x % 2).collect()),
        ("sawtooth", (0..n).map(|x| x % 64).collect()),
        ("organ-pipe", (0..n / 2).chain((0..n / 2).rev()).collect()),
        ("runs-of-64", (0..n).map(|x| (x / 64) ^ 0xAAAA).collect()),
    ];
    for (name, data) in patterns {
        check_sort(&s, &data, name);
    }
}

#[test]
fn all_configs_sort() {
    // Every combination of the Table 2/3 axes sorts correctly.
    for r in [4usize, 8, 16, 32] {
        for net in [ColumnNetwork::Bitonic, ColumnNetwork::OddEven, ColumnNetwork::Best] {
            for width in MergeWidth::all() {
                for imp in [MergeImpl::Vectorized, MergeImpl::Hybrid, MergeImpl::Serial] {
                    let s = NeonMergeSort::new(SortConfig {
                        r,
                        column_network: net,
                        merge_width: width,
                        merge_impl: imp,
                    });
                    let mut rng = Rng::new((r * width.k()) as u64);
                    let data = rng.vec_u32(2000 + r);
                    check_sort(&s, &data, &format!("R={r} {net:?} 2x{} {imp:?}", width.k()));
                }
            }
        }
    }
}

#[test]
fn sorts_i32_and_f32() {
    let s = NeonMergeSort::paper_default();
    let mut rng = Rng::new(5);
    let mut vi = rng.vec_i32(5000);
    s.sort(&mut vi);
    assert_sorted(&vi, "i32");
    let mut vf: Vec<f32> = (0..5000).map(|_| rng.next_f32() * 2e6 - 1e6).collect();
    s.sort(&mut vf);
    assert_sorted(&vf, "f32");
}

#[test]
fn sorts_u64_packed_pairs() {
    use crate::simd::{pack_key_rowid, unpack_key_rowid};
    // The database example path: (key, rowid) packed into u64 sorts by
    // key with rowid tiebreak — via the scalar path (u64 is not a SIMD
    // lane; NeonMergeSort is Lane-generic so this documents the
    // boundary: pairs go through sort_pairs in examples).
    let mut rng = Rng::new(11);
    let mut pairs: Vec<(u32, u32)> =
        (0..1000).map(|i| (rng.next_u32() % 100, i)).collect();
    let mut packed: Vec<u64> = pairs.iter().map(|&(k, r)| pack_key_rowid(k, r)).collect();
    packed.sort_unstable();
    pairs.sort();
    let unpacked: Vec<(u32, u32)> = packed.iter().map(|&p| unpack_key_rowid(p)).collect();
    assert_eq!(unpacked, pairs);
}

#[test]
fn parallel_matches_single_thread() {
    forall(20, |rng| {
        let len = 4096 + rng.below(20_000);
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        NeonMergeSort::paper_default().sort(&mut expect);
        for t in [1usize, 2, 3, 4, 8] {
            let mut v = data.clone();
            ParallelNeonMergeSort::with_threads(t).sort(&mut v);
            assert_eq!(v, expect, "T={t} len={len}");
        }
    });
}

#[test]
fn parallel_small_input_falls_back() {
    let mut rng = Rng::new(3);
    let data = rng.vec_u32(100);
    let mut v = data.clone();
    ParallelNeonMergeSort::with_threads(8).sort(&mut v);
    assert_sorted(&v, "parallel small");
    assert_permutation(&v, &data, "parallel small");
}

#[test]
fn parallel_adversarial() {
    let n = 100_000u32;
    let patterns: Vec<Vec<u32>> = vec![
        (0..n).rev().collect(),
        vec![7; n as usize],
        (0..n).map(|x| x % 3).collect(),
    ];
    for data in patterns {
        let mut v = data.clone();
        ParallelNeonMergeSort::with_threads(4).sort(&mut v);
        assert_sorted(&v, "parallel adversarial");
        assert_permutation(&v, &data, "parallel adversarial");
    }
}

#[test]
fn parallel_odd_thread_counts() {
    let mut rng = Rng::new(17);
    let data = rng.vec_u32(50_001); // non-multiple of block and threads
    for t in [3usize, 5, 7] {
        let mut v = data.clone();
        ParallelNeonMergeSort::with_threads(t).sort(&mut v);
        assert_sorted(&v, &format!("T={t}"));
        assert_permutation(&v, &data, &format!("T={t}"));
    }
}

#[test]
fn parallel_chunk_boundary_sizes_match_oracle() {
    // n straddling the 4096 parallel threshold, n not a multiple of
    // block_len (64), and thread counts exceeding the run count.
    let sizes = [
        4095usize, // just below the threshold → single-thread fallback
        4096,      // exactly at the threshold
        4097,      // just above, not a block multiple
        4160,      // above, exact block multiple
        4161,      // block multiple + 1
        8191,
        12_289, // 192 blocks + 1
    ];
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let data = rng.vec_u32(n);
        let mut expect = data.clone();
        expect.sort_unstable();
        for t in [2usize, 3, 8, 64, 129] {
            let mut v = data.clone();
            ParallelNeonMergeSort::with_threads(t).sort(&mut v);
            assert_eq!(v, expect, "n={n} T={t}");
        }
    }
}

#[test]
fn sort_segments_matches_per_segment_oracle() {
    forall_indexed(40, |case, rng| {
        let nsegs = 1 + case % 9;
        let mut data = Vec::new();
        let mut bounds = vec![0usize];
        for _ in 0..nsegs {
            let len = rng.below(3000); // includes empty segments
            data.extend(rng.vec_u32(len));
            bounds.push(data.len());
        }
        let mut expect = data.clone();
        for w in bounds.windows(2) {
            expect[w[0]..w[1]].sort_unstable();
        }
        for t in [1usize, 2, 4, 16] {
            let mut got = data.clone();
            ParallelNeonMergeSort::with_threads(t).sort_segments(&mut got, &bounds);
            assert_eq!(got, expect, "T={t} segs={nsegs}");
        }
    });
}

#[test]
fn sort_batch_matches_oracle_across_slices() {
    forall(30, |rng| {
        let mut slices: Vec<Vec<u32>> = (0..12)
            .map(|_| {
                let len = rng.below(2000);
                rng.vec_u32(len)
            })
            .collect();
        let expect: Vec<Vec<u32>> = slices
            .iter()
            .map(|s| {
                let mut e = s.clone();
                e.sort_unstable();
                e
            })
            .collect();
        let mut views: Vec<&mut [u32]> = slices.iter_mut().map(|s| s.as_mut_slice()).collect();
        ParallelNeonMergeSort::with_threads(4).sort_batch(&mut views);
        assert_eq!(slices, expect);
    });
}

#[test]
#[should_panic(expected = "bounds must cover data exactly")]
fn sort_segments_rejects_partial_bounds() {
    let mut data = vec![3u32, 1, 2];
    ParallelNeonMergeSort::with_threads(2).sort_segments(&mut data, &[0, 2]);
}

#[test]
fn stability_is_not_claimed_but_order_is_total() {
    // NEON-MS is unstable (like std::sort); verify output equals
    // sort_unstable exactly on u32 (total order ⇒ unique answer).
    forall(30, |rng| {
        let data = rng.vec_u32(10_000);
        let mut expect = data.clone();
        expect.sort_unstable();
        let mut got = data;
        NeonMergeSort::paper_default().sort(&mut got);
        assert_eq!(got, expect);
    });
}
