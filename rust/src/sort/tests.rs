use super::neon_ms::{NeonMergeSort, SortConfig, SortScratch};
use super::parallel::ParallelNeonMergeSort;
use crate::kernels::inregister::ColumnNetwork;
use crate::kernels::{MergeImpl, MergeWidth};
use crate::simd::VectorWidth;
use crate::testutil::{assert_permutation, assert_sorted, forall, forall_indexed, Rng};

fn check_sort(sorter: &NeonMergeSort, data: &[u32], ctx: &str) {
    let mut v = data.to_vec();
    sorter.sort(&mut v);
    assert_sorted(&v, ctx);
    assert_permutation(&v, data, ctx);
}

#[test]
fn sorts_empty_and_tiny() {
    let s = NeonMergeSort::paper_default();
    for len in 0..65usize {
        let mut rng = Rng::new(len as u64);
        check_sort(&s, &rng.vec_u32(len), &format!("len {len}"));
    }
}

#[test]
fn sorts_random_sizes_around_boundaries() {
    let s = NeonMergeSort::paper_default();
    forall_indexed(80, |case, rng| {
        // Cluster sizes around powers of two and block multiples.
        let base = [63usize, 64, 65, 127, 128, 129, 1023, 1024, 4096][case % 9];
        let len = base + rng.below(5);
        check_sort(&s, &rng.vec_u32(len), &format!("len {len}"));
    });
}

#[test]
fn sorts_adversarial_patterns() {
    let s = NeonMergeSort::paper_default();
    let n = 10_000;
    let patterns: Vec<(&str, Vec<u32>)> = vec![
        ("presorted", (0..n).collect()),
        ("reverse", (0..n).rev().collect()),
        ("constant", vec![42; n as usize]),
        ("two-values", (0..n).map(|x| x % 2).collect()),
        ("sawtooth", (0..n).map(|x| x % 64).collect()),
        ("organ-pipe", (0..n / 2).chain((0..n / 2).rev()).collect()),
        ("runs-of-64", (0..n).map(|x| (x / 64) ^ 0xAAAA).collect()),
    ];
    for (name, data) in patterns {
        check_sort(&s, &data, name);
    }
}

#[test]
fn all_configs_sort() {
    // Every combination of the Table 2/3 axes sorts correctly.
    for r in [4usize, 8, 16, 32] {
        for net in [ColumnNetwork::Bitonic, ColumnNetwork::OddEven, ColumnNetwork::Best] {
            for width in MergeWidth::all() {
                for imp in [MergeImpl::Vectorized, MergeImpl::Hybrid, MergeImpl::Serial] {
                    let s = NeonMergeSort::new(SortConfig {
                        r,
                        column_network: net,
                        merge_width: width,
                        merge_impl: imp,
                        vector_width: VectorWidth::V128,
                        backend: None,
                    });
                    let mut rng = Rng::new((r * width.k()) as u64);
                    let data = rng.vec_u32(2000 + r);
                    check_sort(&s, &data, &format!("R={r} {net:?} 2x{} {imp:?}", width.k()));
                }
            }
        }
    }
}

#[test]
fn all_v256_configs_sort() {
    // The full sorter end-to-end at the 8-lane width: every valid
    // R × merge width × impl, sizes crossing block boundaries.
    for r in [8usize, 16, 32] {
        for width in MergeWidth::all() {
            for imp in [MergeImpl::Vectorized, MergeImpl::Hybrid] {
                let s = NeonMergeSort::new(SortConfig {
                    r,
                    column_network: ColumnNetwork::Best,
                    merge_width: width,
                    merge_impl: imp,
                    vector_width: VectorWidth::V256,
                    backend: None,
                });
                let mut rng = Rng::new((r * width.k() + 1) as u64);
                for len in [0usize, 1, r * 8 - 1, r * 8, r * 8 + 1, 3000 + r] {
                    let data = rng.vec_u32(len);
                    check_sort(
                        &s,
                        &data,
                        &format!("V256 R={r} 2x{} {imp:?} len={len}", width.k()),
                    );
                }
            }
        }
    }
}

#[test]
fn v256_matches_v128_output_exactly() {
    // Same totals, unique answer on u32: the two widths must agree
    // element-for-element with each other and the std oracle.
    forall(20, |rng| {
        let len = 4000 + rng.below(70_000);
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        expect.sort_unstable();
        for vw in VectorWidth::all() {
            let s = NeonMergeSort::new(SortConfig {
                merge_width: MergeWidth::K16,
                vector_width: vw,
                ..Default::default()
            });
            let mut got = data.clone();
            s.sort(&mut got);
            assert_eq!(got, expect, "{} len={len}", vw.name());
        }
    });
}

#[test]
fn sort_with_scratch_matches_sort_and_reuses_allocation() {
    let s = NeonMergeSort::paper_default();
    let mut scratch = SortScratch::with_capacity(20_000);
    assert_eq!(scratch.capacity(), 20_000);
    forall_indexed(30, |case, rng| {
        let len = [0usize, 1, 63, 64, 1000, 4096, 20_000][case % 7];
        let data = rng.vec_u32(len);
        let mut a = data.clone();
        let mut b = data.clone();
        s.sort(&mut a);
        s.sort_with_scratch(&mut b, &mut scratch);
        assert_eq!(a, b, "len={len}");
        // Capacity never shrinks and never grows past the high-water
        // mark — the reuse contract the shard workers rely on.
        assert_eq!(scratch.capacity(), 20_000);
    });
    // A larger input grows it once...
    let mut big = Rng::new(9).vec_u32(30_000);
    s.sort_with_scratch(&mut big, &mut scratch);
    assert_sorted(&big, "scratch grow");
    assert_eq!(scratch.capacity(), 30_000);
    // ...and V256 configs share the same scratch.
    let v256 = NeonMergeSort::new(SortConfig {
        vector_width: VectorWidth::V256,
        merge_width: MergeWidth::K32,
        ..Default::default()
    });
    let mut data = Rng::new(10).vec_u32(25_000);
    v256.sort_with_scratch(&mut data, &mut scratch);
    assert_sorted(&data, "V256 via scratch");
    assert_eq!(scratch.capacity(), 30_000);
}

#[test]
fn parallel_sort_with_scratch_matches_oracle() {
    let p = ParallelNeonMergeSort::with_threads(4);
    let mut scratch = SortScratch::new();
    forall(10, |rng| {
        let len = 4096 + rng.below(30_000);
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        expect.sort_unstable();
        let mut got = data;
        p.sort_with_scratch(&mut got, &mut scratch);
        assert_eq!(got, expect, "len={len}");
    });
}

#[test]
fn parallel_v256_matches_single_thread() {
    let cfg = SortConfig {
        vector_width: VectorWidth::V256,
        merge_width: MergeWidth::K64,
        ..Default::default()
    };
    forall(10, |rng| {
        let len = 4096 + rng.below(40_000);
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        expect.sort_unstable();
        for t in [2usize, 4, 7] {
            let mut v = data.clone();
            ParallelNeonMergeSort::new(NeonMergeSort::new(cfg.clone()), t).sort(&mut v);
            assert_eq!(v, expect, "V256 T={t} len={len}");
        }
    });
}

#[test]
fn sort_segments_scratch_matches_plain() {
    forall(15, |rng| {
        let nsegs = 1 + rng.below(8);
        let mut data = Vec::new();
        let mut bounds = vec![0usize];
        for _ in 0..nsegs {
            let len = rng.below(2000);
            data.extend(rng.vec_u32(len));
            bounds.push(data.len());
        }
        let mut plain = data.clone();
        ParallelNeonMergeSort::with_threads(2).sort_segments(&mut plain, &bounds);
        let mut scratch = SortScratch::new();
        let mut via = data;
        ParallelNeonMergeSort::with_threads(2).sort_segments_with_scratch(
            &mut via,
            &bounds,
            &mut scratch,
            |_, _| {},
        );
        assert_eq!(via, plain);
    });
}

#[test]
fn sorts_i32_and_f32() {
    let s = NeonMergeSort::paper_default();
    let mut rng = Rng::new(5);
    let mut vi = rng.vec_i32(5000);
    s.sort(&mut vi);
    assert_sorted(&vi, "i32");
    let mut vf: Vec<f32> = (0..5000).map(|_| rng.next_f32() * 2e6 - 1e6).collect();
    s.sort(&mut vf);
    assert_sorted(&vf, "f32");
}

#[test]
fn sorts_u64_packed_pairs() {
    use crate::simd::{pack_key_rowid, unpack_key_rowid};
    // The database example path: (key, rowid) packed into u64 runs on
    // the real 64-bit SIMD lanes (`V128D`, two lanes per register) and
    // sorts by key with rowid tiebreak.
    let mut rng = Rng::new(11);
    let mut pairs: Vec<(u32, u32)> =
        (0..1000).map(|i| (rng.next_u32() % 100, i)).collect();
    let mut packed: Vec<u64> = pairs.iter().map(|&(k, r)| pack_key_rowid(k, r)).collect();
    NeonMergeSort::paper_default().sort(&mut packed);
    pairs.sort();
    let unpacked: Vec<(u32, u32)> = packed.iter().map(|&p| unpack_key_rowid(p)).collect();
    assert_eq!(unpacked, pairs);
}

#[test]
fn sorts_u64_both_widths_match_oracle() {
    // Full sort on 8-byte lanes at both register widths: block_len is
    // half the u32 one (32 at V128, 64 at V256), K64 clamps to K32,
    // and output must equal sort_unstable exactly (total order).
    for vw in VectorWidth::all() {
        for width in [MergeWidth::K4, MergeWidth::K16, MergeWidth::K64] {
            let s = NeonMergeSort::new(SortConfig {
                merge_width: width,
                vector_width: vw,
                ..Default::default()
            });
            forall_indexed(20, |case, rng| {
                let base = [0usize, 1, 31, 32, 33, 63, 64, 65, 4096][case % 9];
                let len = base + rng.below(3);
                let data = rng.vec_u64(len);
                let mut expect = data.clone();
                expect.sort_unstable();
                let mut got = data;
                s.sort(&mut got);
                assert_eq!(got, expect, "{} 2x{} u64 len={len}", vw.name(), width.k());
            });
        }
    }
}

#[test]
fn sorts_key_value_pairs_with_payload_tiebreak() {
    use crate::simd::KeyValue;
    // Key–payload pairs end-to-end: dup-heavy keys, distinct payloads,
    // so the packed comparison's payload half decides every tie. The
    // pair order is total, so the SIMD result must equal the std
    // oracle byte-for-byte at both widths and through scratch reuse.
    let mut scratch = SortScratch::new();
    for vw in VectorWidth::all() {
        let s = NeonMergeSort::new(SortConfig { vector_width: vw, ..Default::default() });
        forall_indexed(20, |case, rng| {
            let len = [0usize, 1, 33, 64, 1000, 5000][case % 6] + rng.below(3);
            let data: Vec<KeyValue> =
                (0..len).map(|i| KeyValue::new(rng.next_u32() % 16, i as u32)).collect();
            let mut expect = data.clone();
            expect.sort_unstable();
            let mut got = data.clone();
            s.sort(&mut got);
            assert_eq!(got, expect, "{} pair len={len}", vw.name());
            let mut via = data;
            s.sort_with_scratch(&mut via, &mut scratch);
            assert_eq!(via, expect, "{} pair scratch len={len}", vw.name());
        });
    }
}

#[test]
fn parallel_sorts_u64_and_pairs() {
    use crate::simd::KeyValue;
    // The shard/merge parallel path on 8-byte elements: above the
    // parallel threshold, odd thread counts, vs the std oracle.
    forall(8, |rng| {
        let len = 4096 + rng.below(20_000);
        let data = rng.vec_u64(len);
        let mut expect = data.clone();
        expect.sort_unstable();
        for t in [2usize, 3, 8] {
            let mut v = data.clone();
            ParallelNeonMergeSort::with_threads(t).sort(&mut v);
            assert_eq!(v, expect, "u64 T={t} len={len}");
        }
        let pairs: Vec<KeyValue> =
            (0..len).map(|i| KeyValue::new(rng.next_u32() % 100, i as u32)).collect();
        let mut expect = pairs.clone();
        expect.sort_unstable();
        let mut v = pairs;
        ParallelNeonMergeSort::with_threads(4).sort(&mut v);
        assert_eq!(v, expect, "pairs len={len}");
    });
}

#[test]
fn parallel_matches_single_thread() {
    forall(20, |rng| {
        let len = 4096 + rng.below(20_000);
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        NeonMergeSort::paper_default().sort(&mut expect);
        for t in [1usize, 2, 3, 4, 8] {
            let mut v = data.clone();
            ParallelNeonMergeSort::with_threads(t).sort(&mut v);
            assert_eq!(v, expect, "T={t} len={len}");
        }
    });
}

#[test]
fn parallel_small_input_falls_back() {
    let mut rng = Rng::new(3);
    let data = rng.vec_u32(100);
    let mut v = data.clone();
    ParallelNeonMergeSort::with_threads(8).sort(&mut v);
    assert_sorted(&v, "parallel small");
    assert_permutation(&v, &data, "parallel small");
}

#[test]
fn parallel_adversarial() {
    let n = 100_000u32;
    let patterns: Vec<Vec<u32>> = vec![
        (0..n).rev().collect(),
        vec![7; n as usize],
        (0..n).map(|x| x % 3).collect(),
    ];
    for data in patterns {
        let mut v = data.clone();
        ParallelNeonMergeSort::with_threads(4).sort(&mut v);
        assert_sorted(&v, "parallel adversarial");
        assert_permutation(&v, &data, "parallel adversarial");
    }
}

#[test]
fn parallel_odd_thread_counts() {
    let mut rng = Rng::new(17);
    let data = rng.vec_u32(50_001); // non-multiple of block and threads
    for t in [3usize, 5, 7] {
        let mut v = data.clone();
        ParallelNeonMergeSort::with_threads(t).sort(&mut v);
        assert_sorted(&v, &format!("T={t}"));
        assert_permutation(&v, &data, &format!("T={t}"));
    }
}

#[test]
fn parallel_chunk_boundary_sizes_match_oracle() {
    // n straddling the 4096 parallel threshold, n not a multiple of
    // block_len (64), and thread counts exceeding the run count.
    let sizes = [
        4095usize, // just below the threshold → single-thread fallback
        4096,      // exactly at the threshold
        4097,      // just above, not a block multiple
        4160,      // above, exact block multiple
        4161,      // block multiple + 1
        8191,
        12_289, // 192 blocks + 1
    ];
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let data = rng.vec_u32(n);
        let mut expect = data.clone();
        expect.sort_unstable();
        for t in [2usize, 3, 8, 64, 129] {
            let mut v = data.clone();
            ParallelNeonMergeSort::with_threads(t).sort(&mut v);
            assert_eq!(v, expect, "n={n} T={t}");
        }
    }
}

#[test]
fn sort_segments_matches_per_segment_oracle() {
    forall_indexed(40, |case, rng| {
        let nsegs = 1 + case % 9;
        let mut data = Vec::new();
        let mut bounds = vec![0usize];
        for _ in 0..nsegs {
            let len = rng.below(3000); // includes empty segments
            data.extend(rng.vec_u32(len));
            bounds.push(data.len());
        }
        let mut expect = data.clone();
        for w in bounds.windows(2) {
            expect[w[0]..w[1]].sort_unstable();
        }
        for t in [1usize, 2, 4, 16] {
            let mut got = data.clone();
            ParallelNeonMergeSort::with_threads(t).sort_segments(&mut got, &bounds);
            assert_eq!(got, expect, "T={t} segs={nsegs}");
        }
    });
}

#[test]
fn sort_batch_matches_oracle_across_slices() {
    forall(30, |rng| {
        let mut slices: Vec<Vec<u32>> = (0..12)
            .map(|_| {
                let len = rng.below(2000);
                rng.vec_u32(len)
            })
            .collect();
        let expect: Vec<Vec<u32>> = slices
            .iter()
            .map(|s| {
                let mut e = s.clone();
                e.sort_unstable();
                e
            })
            .collect();
        let mut views: Vec<&mut [u32]> = slices.iter_mut().map(|s| s.as_mut_slice()).collect();
        ParallelNeonMergeSort::with_threads(4).sort_batch(&mut views);
        assert_eq!(slices, expect);
    });
}

#[test]
#[should_panic(expected = "bounds must cover data exactly")]
fn sort_segments_rejects_partial_bounds() {
    let mut data = vec![3u32, 1, 2];
    ParallelNeonMergeSort::with_threads(2).sort_segments(&mut data, &[0, 2]);
}

#[test]
fn stability_is_not_claimed_but_order_is_total() {
    // NEON-MS is unstable (like std::sort); verify output equals
    // sort_unstable exactly on u32 (total order ⇒ unique answer).
    forall(30, |rng| {
        let data = rng.vec_u32(10_000);
        let mut expect = data.clone();
        expect.sort_unstable();
        let mut got = data;
        NeonMergeSort::paper_default().sort(&mut got);
        assert_eq!(got, expect);
    });
}
