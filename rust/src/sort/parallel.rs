//! Multi-thread NEON-MS (paper §2.1 third stage + §3.2).
//!
//! Phase 1: split the input into `T` contiguous chunks (rounded to the
//! in-register block so no thread pays a tail penalty except the
//! last); each thread runs the single-thread NEON-MS on its chunk.
//!
//! Phase 2: a merge tree over the `T` sorted runs. At every level,
//! *every pair-merge is partitioned across all threads* with merge
//! path ([`crate::mergepath`]): the pair's output is cut into
//! equal-size segments and all segments of all pairs go into one work
//! list that threads drain — the paper's load-balancing claim ("each
//! available thread remains active") rather than one-thread-per-pair.
//!
//! Uses `std::thread::scope`; no work-stealing runtime is available
//! offline, and none is needed — segments are pre-balanced by
//! construction.

use super::neon_ms::{NeonMergeSort, SortScratch};
use crate::kernels::runmerge::RunMerger;
use crate::mergepath;
use crate::simd::Lane;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Below this many elements (total) a parallel dispatch costs more in
/// thread-scope setup than it saves — fall through to the
/// single-thread sorter (the paper sees the same at small scales in
/// Fig. 5). Shared by [`ParallelNeonMergeSort::sort`] and
/// [`ParallelNeonMergeSort::sort_batch`].
const PARALLEL_MIN_N: usize = 4096;

/// Parallel NEON-MS sorter.
#[derive(Clone, Debug)]
pub struct ParallelNeonMergeSort {
    single: NeonMergeSort,
    threads: usize,
}

/// Sendable raw output window; each segment writes a disjoint range,
/// so handing threads overlapping `&mut` views is safe by
/// construction (checked in debug by the mergepath tests).
struct OutPtr<T>(*mut T);
unsafe impl<T: Send> Send for OutPtr<T> {}
unsafe impl<T: Send> Sync for OutPtr<T> {}

impl ParallelNeonMergeSort {
    /// Build with an explicit thread count (the paper sweeps T; its
    /// testbed used 64).
    pub fn new(single: NeonMergeSort, threads: usize) -> Self {
        assert!(threads >= 1);
        ParallelNeonMergeSort { single, threads }
    }

    /// Paper defaults with `threads`.
    pub fn with_threads(threads: usize) -> Self {
        ParallelNeonMergeSort::new(NeonMergeSort::paper_default(), threads)
    }

    /// Thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Sort each contiguous segment `data[bounds[i]..bounds[i + 1]]`
    /// independently — the fused-buffer form of [`Self::sort_batch`]:
    /// the coordinator's dynamic batcher concatenates many small
    /// requests into one buffer with recorded offsets and relies on
    /// this to amortize thread-scope setup across the whole batch
    /// instead of paying it per request.
    ///
    /// `bounds` must start at 0, end at `data.len()`, and be
    /// non-decreasing.
    ///
    /// # Examples
    ///
    /// ```
    /// use neonms::sort::ParallelNeonMergeSort;
    ///
    /// let mut fused = vec![3u32, 1, 2, 9, 7, 8];
    /// ParallelNeonMergeSort::with_threads(2).sort_segments(&mut fused, &[0, 3, 6]);
    /// assert_eq!(fused, [1, 2, 3, 7, 8, 9]); // each segment sorted on its own
    /// ```
    pub fn sort_segments<T: Lane>(&self, data: &mut [T], bounds: &[usize]) {
        self.sort_segments_with(data, bounds, |_, _| {});
    }

    /// [`Self::sort_segments`] with a completion hook: `on_sorted(i,
    /// segment)` fires on the sorting thread the moment segment `i`
    /// is fully sorted, while the rest of the batch may still be in
    /// flight. The service's dynamic batcher uses this to complete
    /// each fused request's handle as soon as *its* data is ready
    /// instead of when the whole batch finishes — and, since the
    /// coordinator's fair-share QoS charges admission in elements,
    /// the hook is also where each fused request's in-flight cost is
    /// released back to its tenant (the per-segment completion is the
    /// service's QoS accounting point, not just a latency
    /// optimization).
    ///
    /// The hook is called exactly once per segment, from whichever
    /// worker sorted it (hence `Sync`); segment indices follow
    /// `bounds` order but completion order is unspecified.
    pub fn sort_segments_with<T, F>(&self, data: &mut [T], bounds: &[usize], on_sorted: F)
    where
        T: Lane,
        F: Fn(usize, &[T]) + Sync,
    {
        self.sort_segments_with_scratch(data, bounds, &mut SortScratch::new(), on_sorted);
    }

    /// [`Self::sort_segments_with`] against caller-owned scratch —
    /// the service's shard workers call this: for the common inline
    /// batch (total below the parallel threshold, sorted on the
    /// calling thread) **all** auxiliary memory comes from `scratch`,
    /// so steady-state fused batches allocate nothing.
    pub fn sort_segments_with_scratch<T, F>(
        &self,
        data: &mut [T],
        bounds: &[usize],
        scratch: &mut SortScratch<T>,
        on_sorted: F,
    ) where
        T: Lane,
        F: Fn(usize, &[T]) + Sync,
    {
        assert!(
            !bounds.is_empty() && bounds[0] == 0 && *bounds.last().unwrap() == data.len(),
            "bounds must cover data exactly"
        );
        assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "bounds must be non-decreasing");
        let mut views: Vec<&mut [T]> = Vec::with_capacity(bounds.len() - 1);
        let mut rest = data;
        let mut prev = 0;
        for &b in &bounds[1..] {
            let (head, tail) = rest.split_at_mut(b - prev);
            prev = b;
            rest = tail;
            views.push(head);
        }
        self.sort_batch_with_scratch(&mut views, scratch, on_sorted);
    }

    /// Multi-slice batch entry point: sort many independent slices in
    /// one cooperative pass, all slices drained from one shared work
    /// list by a single `thread::scope`. Batches whose total is below
    /// the parallel threshold are sorted inline without spawning.
    pub fn sort_batch<T: Lane>(&self, slices: &mut [&mut [T]]) {
        self.sort_batch_with(slices, |_, _| {});
    }

    /// [`Self::sort_batch`] with a per-slice completion hook — the
    /// slice-of-slices twin of [`Self::sort_segments_with`], same
    /// contract: `on_sorted(k, slice)` fires exactly once per slice,
    /// on the thread that sorted it, as soon as it is sorted.
    pub fn sort_batch_with<T, F>(&self, slices: &mut [&mut [T]], on_sorted: F)
    where
        T: Lane,
        F: Fn(usize, &[T]) + Sync,
    {
        self.sort_batch_with_scratch(slices, &mut SortScratch::new(), on_sorted);
    }

    /// [`Self::sort_batch_with`] against caller-owned scratch. The
    /// inline path (small batches) sorts every slice on the calling
    /// thread through `scratch`; the spawning path gives each worker
    /// thread its own scratch reused across all slices it claims, so
    /// aux allocation is once per worker per batch instead of once
    /// per slice.
    pub fn sort_batch_with_scratch<T, F>(
        &self,
        slices: &mut [&mut [T]],
        scratch: &mut SortScratch<T>,
        on_sorted: F,
    ) where
        T: Lane,
        F: Fn(usize, &[T]) + Sync,
    {
        let n = slices.len();
        let total: usize = slices.iter().map(|s| s.len()).sum();
        let t = self.threads.min(n);
        if t <= 1 || total < PARALLEL_MIN_N {
            for (k, sl) in slices.iter_mut().enumerate() {
                self.single.sort_with_scratch(sl, scratch);
                on_sorted(k, &**sl);
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        let ptr = OutPtr(slices.as_mut_ptr());
        let single = &self.single;
        let on_sorted = &on_sorted;
        std::thread::scope(|s| {
            for _ in 0..t {
                let cursor = &cursor;
                let ptr = &ptr;
                s.spawn(move || {
                    let mut local = SortScratch::new();
                    loop {
                        let k = cursor.fetch_add(1, Ordering::Relaxed);
                        if k >= n {
                            break;
                        }
                        // SAFETY: each index is claimed by exactly one
                        // thread and the `&mut [T]` entries are
                        // disjoint by construction.
                        let sl: &mut &mut [T] = unsafe { &mut *ptr.0.add(k) };
                        single.sort_with_scratch(sl, &mut local);
                        on_sorted(k, &**sl);
                    }
                });
            }
        });
    }

    /// Sort `data` ascending in place.
    ///
    /// # Examples
    ///
    /// ```
    /// use neonms::sort::ParallelNeonMergeSort;
    ///
    /// let sorter = ParallelNeonMergeSort::with_threads(2);
    /// let mut data: Vec<u32> = (0..10_000).rev().collect();
    /// sorter.sort(&mut data);
    /// assert!(data.windows(2).all(|w| w[0] <= w[1]));
    /// ```
    pub fn sort<T: Lane>(&self, data: &mut [T]) {
        self.sort_with_scratch(data, &mut SortScratch::new());
    }

    /// [`Self::sort`] against caller-owned scratch: the merge tree's
    /// ping-pong buffer (and, below the parallel threshold, the
    /// single-thread sorter's aux) comes from `scratch`, so a worker
    /// that owns one does zero per-job heap allocation in steady
    /// state. Phase 1's per-chunk local sorts still allocate their
    /// thread-local aux inside the spawned scope (scratch is one
    /// buffer and the chunk sorts run concurrently).
    pub fn sort_with_scratch<T: Lane>(&self, data: &mut [T], scratch: &mut SortScratch<T>) {
        let n = data.len();
        let t = self.threads;
        if t == 1 || n < PARALLEL_MIN_N {
            // Parallel overhead dominates below the threshold.
            return self.single.sort_with_scratch(data, scratch);
        }
        // ---- Phase 1: local sorts on contiguous chunks ----
        let block = self.single.inregister().block_len_for::<T>();
        let chunk = (n / t / block).max(1) * block;
        let mut bounds: Vec<usize> = (0..t).map(|i| (i * chunk).min(n)).collect();
        bounds.push(n);
        {
            let mut rest: &mut [T] = data;
            let mut slices: Vec<&mut [T]> = Vec::with_capacity(t);
            let mut prev = 0;
            for w in bounds.windows(2).skip(0) {
                let (head, tail) = rest.split_at_mut(w[1] - prev);
                prev = w[1];
                rest = tail;
                slices.push(head);
            }
            std::thread::scope(|s| {
                for sl in slices {
                    let single = &self.single;
                    s.spawn(move || single.sort(sl));
                }
            });
        }
        // ---- Phase 2: cooperative merge tree ----
        let mut runs: Vec<(usize, usize)> = bounds
            .windows(2)
            .map(|w| (w[0], w[1]))
            .filter(|(a, b)| a < b)
            .collect();
        let aux = scratch.take(n);
        let mut src_is_data = true;
        while runs.len() > 1 {
            let (src, dst): (&[T], &mut [T]) = if src_is_data {
                (&*data, &mut aux[..])
            } else {
                (&aux[..], data)
            };
            runs = self.merge_level(src, dst, &runs);
            src_is_data = !src_is_data;
        }
        if !src_is_data {
            data.copy_from_slice(aux);
        }
    }

    /// Merge adjacent run pairs from `src` into `dst`, all pairs
    /// partitioned into one balanced work list drained by all threads.
    fn merge_level<T: Lane>(
        &self,
        src: &[T],
        dst: &mut [T],
        runs: &[(usize, usize)],
    ) -> Vec<(usize, usize)> {
        let t = self.threads;
        let total: usize = runs.iter().map(|(a, b)| b - a).sum();
        // Build the global segment list.
        struct Task {
            a_lo: usize,
            a_hi: usize,
            b_lo: usize,
            b_hi: usize,
            out_lo: usize,
        }
        let mut tasks: Vec<Task> = Vec::new();
        let mut next_runs = Vec::with_capacity(runs.len().div_ceil(2));
        let mut pair_iter = runs.chunks(2);
        for pair in &mut pair_iter {
            match pair {
                [(a0, a1), (b0, b1)] => {
                    next_runs.push((*a0, *b1));
                    let a = &src[*a0..*a1];
                    let b = &src[*b0..*b1];
                    // Proportional share of the thread pool, ≥ 1.
                    let p = ((a.len() + b.len()) * t).div_ceil(total.max(1)).max(1);
                    for seg in mergepath::partition(a, b, p) {
                        tasks.push(Task {
                            a_lo: a0 + seg.a_lo,
                            a_hi: a0 + seg.a_hi,
                            b_lo: b0 + seg.b_lo,
                            b_hi: b0 + seg.b_hi,
                            out_lo: a0 + seg.out_lo,
                        });
                    }
                }
                [(a0, a1)] => {
                    next_runs.push((*a0, *a1));
                    tasks.push(Task { a_lo: *a0, a_hi: *a1, b_lo: *a1, b_hi: *a1, out_lo: *a0 });
                }
                _ => unreachable!(),
            }
        }
        // Drain the work list with an atomic cursor.
        let cursor = AtomicUsize::new(0);
        let out = OutPtr(dst.as_mut_ptr());
        let merger: &RunMerger = self.single.merger();
        std::thread::scope(|s| {
            for _ in 0..t.min(tasks.len()) {
                let cursor = &cursor;
                let tasks = &tasks;
                let out = &out;
                s.spawn(move || loop {
                    let k = cursor.fetch_add(1, Ordering::Relaxed);
                    if k >= tasks.len() {
                        break;
                    }
                    let tk = &tasks[k];
                    let a = &src[tk.a_lo..tk.a_hi];
                    let b = &src[tk.b_lo..tk.b_hi];
                    // SAFETY: segments write disjoint output ranges
                    // [out_lo, out_lo + a.len() + b.len()).
                    let dst_seg = unsafe {
                        std::slice::from_raw_parts_mut(
                            out.0.add(tk.out_lo),
                            a.len() + b.len(),
                        )
                    };
                    merger.merge(a, b, dst_seg);
                });
            }
        });
        next_runs
    }
}
