//! Single-thread NEON-MS (paper §2.1): in-register sort pass +
//! ping-pong vectorized merge passes.

use crate::kernels::inregister::{ColumnNetwork, InRegisterSorter};
use crate::kernels::runmerge::RunMerger;
use crate::kernels::{MergeImpl, MergeWidth};
use crate::simd::{Backend, Lane, VectorWidth};

/// Reusable auxiliary memory for [`NeonMergeSort::sort_with_scratch`]
/// and [`super::ParallelNeonMergeSort::sort_with_scratch`]: the
/// ping-pong merge buffer, grown on demand and kept across calls so
/// steady-state callers (the service's shard workers) do zero per-job
/// heap allocation.
///
/// One scratch serves any number of sequential sorts of any sizes;
/// it is `Send`, so a worker thread can own one for its lifetime.
#[derive(Debug)]
pub struct SortScratch<T: Lane> {
    buf: Vec<T>,
}

impl<T: Lane> Default for SortScratch<T> {
    fn default() -> Self {
        SortScratch::new()
    }
}

impl<T: Lane> SortScratch<T> {
    /// Empty scratch; grows on first use.
    pub fn new() -> Self {
        SortScratch { buf: Vec::new() }
    }

    /// Scratch pre-sized for inputs up to `n` elements (no growth —
    /// and therefore no allocation — for any sort ≤ `n`).
    pub fn with_capacity(n: usize) -> Self {
        SortScratch { buf: vec![T::MIN_VALUE; n] }
    }

    /// Current capacity in elements (for tests/metrics).
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// An `n`-element aux view, growing the buffer only when `n`
    /// exceeds every earlier request (amortized allocation-free).
    pub(crate) fn take(&mut self, n: usize) -> &mut [T] {
        if self.buf.len() < n {
            self.buf.resize(n, T::MIN_VALUE);
        }
        &mut self.buf[..n]
    }
}

/// Tuning knobs for the full sort — every Table 2/3 axis in one place,
/// plus the register-width axis the width sweep added.
#[derive(Clone, Debug)]
pub struct SortConfig {
    /// Registers for the in-register sort (paper: 16).
    pub r: usize,
    /// Column-sort network family (paper: best, the `16*` row).
    pub column_network: ColumnNetwork,
    /// Register-merge kernel width for the merge passes, up to the
    /// `MAX_K = 64` budget (2×64). The paper's Table 3 finds the
    /// hybrid merger fastest at 2×{8,16}, and the recorded width
    /// sweep's full-sort winner agrees (`BENCH_width_sweep.json`
    /// `best_fullsort`: hybrid 2×16 at `V128`), so 2×16 is the
    /// default. Re-run the sweep (`cargo bench --bench ablations`, or
    /// take the CI artifact) and re-tune on your own hardware; the
    /// benches sweep all widths at both register widths.
    pub merge_width: MergeWidth,
    /// Merge kernel implementation (paper: hybrid).
    pub merge_impl: MergeImpl,
    /// Register width both stages run at. `V256` models paired
    /// q-registers / SVE-256 (each op lowers to two `V128` ops on
    /// paired-register backends) and requires `r % 8 == 0`.
    pub vector_width: VectorWidth,
    /// SIMD backend override. `None` (the default) keeps whatever the
    /// process already selected — runtime detection, or the
    /// `NEONMS_SIMD_BACKEND` environment variable. `Some(backend)`
    /// forces that lowering process-wide at sorter construction
    /// ([`crate::simd::backend::force`]); forcing
    /// [`Backend::Scalar`] always succeeds, forcing an unavailable
    /// intrinsic backend panics rather than silently falling back.
    pub backend: Option<Backend>,
}

impl Default for SortConfig {
    fn default() -> Self {
        SortConfig {
            r: 16,
            column_network: ColumnNetwork::Best,
            merge_width: MergeWidth::K16,
            merge_impl: MergeImpl::Hybrid,
            vector_width: VectorWidth::V128,
            backend: None,
        }
    }
}

/// The single-thread NEON-MS sorter. Construction precomputes the
/// column network; [`NeonMergeSort::sort`] is then allocation-free
/// apart from one ping-pong buffer of the input's size — and
/// [`NeonMergeSort::sort_with_scratch`] reuses even that across
/// calls.
#[derive(Clone, Debug)]
pub struct NeonMergeSort {
    inreg: InRegisterSorter,
    merger: RunMerger,
}

impl NeonMergeSort {
    /// Build from a config.
    ///
    /// # Panics
    ///
    /// Panics if `cfg.backend` names a SIMD backend unavailable on
    /// this machine (same contract as the `r` validation asserts:
    /// construction is where configs fail loudly). The service
    /// pre-validates and returns an error instead.
    pub fn new(cfg: SortConfig) -> Self {
        if let Some(k) = cfg.backend {
            if let Err(e) = crate::simd::backend::force(k) {
                panic!("SortConfig.backend: {e}");
            }
        }
        let inreg = InRegisterSorter::new(cfg.r, cfg.column_network)
            .with_vector(cfg.vector_width)
            .with_merge_impl(match cfg.merge_impl {
                MergeImpl::Serial => MergeImpl::Hybrid, // row merge stays in-register
                other => other,
            });
        let merger =
            RunMerger { width: cfg.merge_width, imp: cfg.merge_impl, vector: cfg.vector_width };
        NeonMergeSort { inreg, merger }
    }

    /// The paper's configuration: R = 16* with hybrid merges (width
    /// sweep-tuned to 2×16 at V128; see SortConfig::merge_width).
    pub fn paper_default() -> Self {
        NeonMergeSort::new(SortConfig::default())
    }

    /// Access the in-register stage (benches sweep it directly).
    pub fn inregister(&self) -> &InRegisterSorter {
        &self.inreg
    }

    /// Access the run merger.
    pub fn merger(&self) -> &RunMerger {
        &self.merger
    }

    /// Elements per cache-resident segment: segment + ping-pong aux =
    /// 2 × 256 KiB, sized to stay L2-resident during the early merge
    /// passes (§Perf iteration 6 — breadth-first passes streamed the
    /// whole array through DRAM log2(n/64) times).
    const SEGMENT: usize = 64 * 1024;

    /// Sort `data` ascending in place. `O(n)` auxiliary memory (one
    /// ping-pong buffer), `O(n log n)` time. Cache-blocked: segments
    /// of `SEGMENT` elements are fully sorted with in-cache merge
    /// passes first, then the outer passes merge segments.
    ///
    /// Allocates the aux buffer per call; steady-state callers should
    /// hold a [`SortScratch`] and use
    /// [`NeonMergeSort::sort_with_scratch`].
    ///
    /// # Examples
    ///
    /// ```
    /// use neonms::sort::NeonMergeSort;
    ///
    /// let sorter = NeonMergeSort::paper_default();
    /// let mut data: Vec<u32> = (0..500).rev().collect();
    /// sorter.sort(&mut data); // 500 > one 64-element block → vector path
    /// assert_eq!(data, (0..500).collect::<Vec<u32>>());
    ///
    /// let mut tiny = vec![9u32, 3, 7];
    /// sorter.sort(&mut tiny); // below one block → insertion sort
    /// assert_eq!(tiny, [3, 7, 9]);
    /// ```
    pub fn sort<T: Lane>(&self, data: &mut [T]) {
        self.sort_with_scratch(data, &mut SortScratch::new());
    }

    /// [`NeonMergeSort::sort`] against caller-owned auxiliary memory:
    /// after `scratch` has grown to the largest input seen, further
    /// sorts perform **zero** heap allocation — the reusable-scratch
    /// entry point the service's shard workers run on.
    ///
    /// # Examples
    ///
    /// ```
    /// use neonms::sort::{NeonMergeSort, SortScratch};
    ///
    /// let sorter = NeonMergeSort::paper_default();
    /// let mut scratch = SortScratch::with_capacity(1024);
    /// for seed in 0..4u32 {
    ///     let mut data: Vec<u32> = (0..1024).map(|i| i ^ seed).collect();
    ///     sorter.sort_with_scratch(&mut data, &mut scratch); // no allocation
    ///     assert!(data.windows(2).all(|w| w[0] <= w[1]));
    /// }
    /// ```
    pub fn sort_with_scratch<T: Lane>(&self, data: &mut [T], scratch: &mut SortScratch<T>) {
        let n = data.len();
        if n <= 1 {
            return;
        }
        if n < self.inreg.block_len_for::<T>() {
            crate::kernels::serial::insertion_sort(data);
            return;
        }
        let aux = scratch.take(n);
        // Phase A: segment-local sort (in-register pass + in-cache
        // merge passes), each segment independent.
        for (seg, seg_aux) in data.chunks_mut(Self::SEGMENT).zip(aux.chunks_mut(Self::SEGMENT)) {
            self.sort_segment(seg, seg_aux);
        }
        // Phase B: outer merge passes over whole segments.
        let mut run = Self::SEGMENT;
        let mut src_is_data = true;
        while run < n {
            {
                let (src, dst): (&mut [T], &mut [T]) =
                    if src_is_data { (data, &mut aux[..]) } else { (&mut aux[..], data) };
                self.merge_pass(src, dst, run);
            }
            src_is_data = !src_is_data;
            run *= 2;
        }
        if !src_is_data {
            data.copy_from_slice(aux);
        }
    }

    /// Fully sort one cache-sized segment using `seg_aux` as the
    /// ping-pong buffer (result always ends in `seg`).
    fn sort_segment<T: Lane>(&self, seg: &mut [T], seg_aux: &mut [T]) {
        let n = seg.len();
        let mut run = self.inreg.sort_runs(seg);
        let mut src_is_data = true;
        while run < n {
            {
                let (src, dst): (&mut [T], &mut [T]) = if src_is_data {
                    (&mut *seg, &mut seg_aux[..n])
                } else {
                    (&mut seg_aux[..n], &mut *seg)
                };
                self.merge_pass(src, dst, run);
            }
            src_is_data = !src_is_data;
            run *= 2;
        }
        if !src_is_data {
            seg.copy_from_slice(&seg_aux[..n]);
        }
    }

    /// One merge pass: merge adjacent run pairs of length `run` from
    /// `src` into `dst` (the last run may be short / unpaired).
    fn merge_pass<T: Lane>(&self, src: &[T], dst: &mut [T], run: usize) {
        let n = src.len();
        let mut base = 0;
        while base < n {
            let mid = (base + run).min(n);
            let end = (base + 2 * run).min(n);
            if mid < end {
                self.merger.merge(&src[base..mid], &src[mid..end], &mut dst[base..end]);
            } else {
                dst[base..end].copy_from_slice(&src[base..end]);
            }
            base = end;
        }
    }

    /// Sort into a fresh vector (convenience for the coordinator).
    pub fn sorted<T: Lane>(&self, input: &[T]) -> Vec<T> {
        let mut v = input.to_vec();
        self.sort(&mut v);
        v
    }
}
