//! The NEON-MS sort itself (paper §2.1, Fig. 1).
//!
//! * [`neon_ms`] — the single-thread sort: one in-register-sort pass
//!   producing sorted runs of `R·W = 64`, then ping-pong vectorized
//!   merge passes (hybrid bitonic kernels) doubling the run length
//!   until the slice is one run.
//! * [`parallel`] — the multi-thread version: per-thread local sorts,
//!   then a cooperative merge tree where every pair-merge is
//!   partitioned across *all* threads by merge path (§2.1's data
//!   partitioning strategy [10]) so "each available thread remains
//!   active" (§3.2).

pub mod neon_ms;
pub mod parallel;

pub use neon_ms::{NeonMergeSort, SortConfig, SortScratch};
pub use parallel::ParallelNeonMergeSort;

#[cfg(test)]
mod tests;
