//! Sorting & merging networks (paper §2.3, Table 1).
//!
//! A network is a fixed sequence of two-element comparators. NEON-MS
//! uses networks in two roles:
//!
//! * **Column sort** — one comparator per *register pair*, executed
//!   lane-wise as `vmin`+`vmax` ([`Network::apply_columns`]). Because
//!   each comparator costs exactly two vector ops regardless of the
//!   network's structural regularity, the *asymmetric* best-known
//!   networks (fewest comparators) win here — the paper's key §2.3
//!   observation. Symmetric bitonic/odd-even structure buys nothing.
//! * **Merging** — bitonic and odd-even *merging* networks combine two
//!   sorted runs; these feed the vectorized and hybrid mergers in
//!   [`crate::kernels`] and the cost model in [`crate::regmachine`].
//!
//! Families provided (Table 1 columns):
//!
//! | family | generator | n=4 | n=8 | n=16 | n=32 |
//! |---|---|---|---|---|---|
//! | bitonic | [`gen::bitonic_sort`] | 6 | 24 | 80 | 240 |
//! | odd-even (Batcher) | [`gen::odd_even_sort`] | 5 | 19 | 63 | 191 |
//! | asymmetric best | [`gen::best`] | 5 | 19 | 60 | 185 |
//!
//! Every constructor is checked by the zero-one-principle verifier
//! ([`Network::verify_zero_one`], exhaustive over all `2^n` patterns).
//!
//! # Invariants
//!
//! * A [`Network`] is a *fixed*, data-oblivious comparator sequence:
//!   applying it executes every comparator in order regardless of
//!   input — which is precisely why comparator *count*, not
//!   structure, is the column-sort cost (the asymmetric-best
//!   argument above).
//! * Every comparator `(i, j)` has `i < j` and orders min→`i`,
//!   max→`j`; sorting networks sort ascending.
//! * Sorting networks satisfy the zero-one principle (verified
//!   exhaustively in tests for every generated size); merging
//!   networks additionally assume each input half is sorted and are
//!   verified by [`Network::verify_bitonic_merge`].

mod network;
pub mod gen;
mod best_tables;
mod verify;

pub use network::{Comparator, Network};

#[cfg(test)]
mod tests;
