//! Network representation, application, and structural statistics.

use crate::simd::{Lane, Vector};

/// One compare-exchange: after execution, position `i` holds the
/// minimum and position `j` the maximum of the pair.
///
/// `i` and `j` are *positions*, not ordered indices — directional
/// comparators (min to the higher address) are expressed as `i > j`,
/// which the bitonic generator uses for its descending half.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct Comparator {
    /// Receives the minimum.
    pub i: u16,
    /// Receives the maximum.
    pub j: u16,
}

impl Comparator {
    /// Construct a comparator routing min→`i`, max→`j`.
    pub fn new(i: usize, j: usize) -> Self {
        debug_assert_ne!(i, j);
        Comparator { i: i as u16, j: j as u16 }
    }
}

/// A comparator network over `n` channels.
#[derive(Clone, Debug)]
pub struct Network {
    n: usize,
    comps: Vec<Comparator>,
    name: String,
}

impl Network {
    /// Build from an explicit comparator list.
    pub fn new(name: impl Into<String>, n: usize, comps: Vec<Comparator>) -> Self {
        let name = name.into();
        for c in &comps {
            assert!(
                (c.i as usize) < n && (c.j as usize) < n,
                "{name}: comparator ({}, {}) out of range for n={n}",
                c.i,
                c.j
            );
        }
        Network { n, comps, name }
    }

    /// Number of input channels.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Human-readable family name (e.g. `"best-16"`).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The comparator sequence.
    pub fn comparators(&self) -> &[Comparator] {
        &self.comps
    }

    /// Comparator count — the paper's Table 1 efficiency metric.
    pub fn size(&self) -> usize {
        self.comps.len()
    }

    /// Critical-path depth: minimum number of parallel layers when
    /// comparators touching disjoint channels execute together.
    /// Greedy ASAP layering (optimal for a fixed sequence).
    pub fn depth(&self) -> usize {
        let mut ready = vec![0usize; self.n];
        let mut depth = 0;
        for c in &self.comps {
            let at = ready[c.i as usize].max(ready[c.j as usize]) + 1;
            ready[c.i as usize] = at;
            ready[c.j as usize] = at;
            depth = depth.max(at);
        }
        depth
    }

    /// Group comparators into ASAP parallel layers. Within one layer no
    /// channel is touched twice, so a vector engine (or the regmachine
    /// cost model) may execute the whole layer concurrently.
    pub fn layers(&self) -> Vec<Vec<Comparator>> {
        let mut ready = vec![0usize; self.n];
        let mut out: Vec<Vec<Comparator>> = Vec::new();
        for &c in &self.comps {
            let at = ready[c.i as usize].max(ready[c.j as usize]);
            ready[c.i as usize] = at + 1;
            ready[c.j as usize] = at + 1;
            if out.len() <= at {
                out.resize_with(at + 1, Vec::new);
            }
            out[at].push(c);
        }
        out
    }

    /// Run the network on a scalar slice (`data.len() == n`). This is
    /// the paper's Fig. 3b comparator: branchless min/max, compiled to
    /// `cmov`-class code — used by the serial half of the hybrid merger
    /// and as the oracle for column application.
    #[inline]
    pub fn apply_slice<T: Lane>(&self, data: &mut [T]) {
        assert_eq!(data.len(), self.n, "{}: slice length mismatch", self.name);
        for c in &self.comps {
            let (a, b) = (data[c.i as usize], data[c.j as usize]);
            data[c.i as usize] = a.lane_min(b);
            data[c.j as usize] = a.lane_max(b);
        }
    }

    /// Run the network *column-wise* over a register file: comparator
    /// `(i, j)` becomes a single vector `cmpswap` between registers `i`
    /// and `j`, sorting all `W` columns simultaneously (paper §2.3).
    /// Width-generic: columns never interact, so the same comparator
    /// stream sorts 4 columns on [`crate::simd::V128`] and 8 on
    /// [`crate::simd::V256`].
    #[inline]
    pub fn apply_columns<T: Lane, V: Vector<T>>(&self, regs: &mut [V]) {
        assert_eq!(regs.len(), self.n, "{}: register count mismatch", self.name);
        for c in &self.comps {
            let (lo, hi) = regs[c.i as usize].cmpswap(regs[c.j as usize]);
            regs[c.i as usize] = lo;
            regs[c.j as usize] = hi;
        }
    }

    /// Concatenate: run `self`, then `other` (same channel count).
    pub fn then(mut self, other: &Network) -> Network {
        assert_eq!(self.n, other.n);
        self.comps.extend_from_slice(&other.comps);
        self.name = format!("{}+{}", self.name, other.name);
        self
    }

    /// Embed this network at channel offset `off` within a wider
    /// `n_total`-channel network (used to build sorters from parts,
    /// e.g. best-32 = two offset best-16 sorters + an odd-even merge).
    pub fn offset(&self, off: usize, n_total: usize) -> Network {
        assert!(off + self.n <= n_total);
        let comps = self
            .comps
            .iter()
            .map(|c| Comparator::new(c.i as usize + off, c.j as usize + off))
            .collect();
        Network::new(format!("{}@{}", self.name, off), n_total, comps)
    }

    /// Verify by the zero-one principle (exhaustive over `2^n` binary
    /// inputs; `n ≤ 26` guard). Returns `true` iff the network sorts
    /// every input.
    pub fn verify_zero_one(&self) -> bool {
        super::verify::verify_zero_one(self)
    }

    /// Check this network *merges*: sorts every input consisting of two
    /// already-sorted halves `[0, split)` and `[split, n)`. Exhaustive
    /// over zero-one inputs with both halves sorted — `(split+1) *
    /// (n-split+1)` cases, so cheap even for large n.
    pub fn verify_merge(&self, split: usize) -> bool {
        super::verify::verify_merge(self, split)
    }

    /// Check this network sorts every *bitonic* zero-one input
    /// (ascending then descending rotations thereof are not required —
    /// the kernels only feed asc⌢desc concatenations).
    pub fn verify_bitonic_merge(&self) -> bool {
        super::verify::verify_bitonic(self)
    }
}

impl core::fmt::Display for Network {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(
            f,
            "{} (n={}, {} comparators, depth {})",
            self.name,
            self.n,
            self.size(),
            self.depth()
        )
    }
}
