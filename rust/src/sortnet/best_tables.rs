//! Hard-coded best-known sorting networks, n ≤ 16.
//!
//! Sources: Knuth TAOCP vol. 3 §5.3.4 and John Gamble's network
//! generator (paper ref. [5]). Sizes: 0/1/3/5/9/12/16/19 for n = 1..8
//! (all proven optimal) and 60 for n = 16 (Green's construction, best
//! known; proven lower bound 55 — hence Table 1's `55~60` range).
//!
//! Every table is verified exhaustively by the zero-one principle in
//! this module's test suite *and* re-verified at construction time in
//! debug builds; the Python copies in
//! `python/compile/kernels/networks.py` are cross-checked against the
//! same principle in `python/tests/test_networks.py`.

use super::network::Comparator;

macro_rules! comps {
    ($(($i:expr, $j:expr)),* $(,)?) => {
        vec![$(Comparator::new($i, $j)),*]
    };
}

/// Return the best-known comparator list for `n`, if tabulated.
pub fn table(n: usize) -> Option<Vec<Comparator>> {
    let comps = match n {
        1 => vec![],
        2 => comps![(0, 1)],
        3 => comps![(1, 2), (0, 2), (0, 1)],
        4 => comps![(0, 1), (2, 3), (0, 2), (1, 3), (1, 2)],
        5 => comps![
            (0, 1),
            (3, 4),
            (2, 4),
            (2, 3),
            (1, 4),
            (0, 3),
            (0, 2),
            (1, 3),
            (1, 2)
        ],
        6 => comps![
            (1, 2),
            (4, 5),
            (0, 2),
            (3, 5),
            (0, 1),
            (3, 4),
            (2, 5),
            (0, 3),
            (1, 4),
            (2, 4),
            (1, 3),
            (2, 3)
        ],
        7 => comps![
            (1, 2),
            (3, 4),
            (5, 6),
            (0, 2),
            (3, 5),
            (4, 6),
            (0, 1),
            (4, 5),
            (2, 6),
            (0, 4),
            (1, 5),
            (0, 3),
            (2, 5),
            (1, 3),
            (2, 4),
            (2, 3)
        ],
        8 => comps![
            (0, 1),
            (2, 3),
            (4, 5),
            (6, 7),
            (0, 2),
            (1, 3),
            (4, 6),
            (5, 7),
            (1, 2),
            (5, 6),
            (0, 4),
            (3, 7),
            (1, 5),
            (2, 6),
            (1, 4),
            (3, 6),
            (2, 4),
            (3, 5),
            (3, 4)
        ],
        // Green's 60-comparator, depth-10 network for 16 inputs —
        // the paper's "best 16-element sorting network" (16*).
        16 => comps![
            // layer 1
            (0, 1),
            (2, 3),
            (4, 5),
            (6, 7),
            (8, 9),
            (10, 11),
            (12, 13),
            (14, 15),
            // layer 2
            (0, 2),
            (4, 6),
            (8, 10),
            (12, 14),
            (1, 3),
            (5, 7),
            (9, 11),
            (13, 15),
            // layer 3
            (0, 4),
            (8, 12),
            (1, 5),
            (9, 13),
            (2, 6),
            (10, 14),
            (3, 7),
            (11, 15),
            // layer 4
            (0, 8),
            (1, 9),
            (2, 10),
            (3, 11),
            (4, 12),
            (5, 13),
            (6, 14),
            (7, 15),
            // layer 5
            (5, 10),
            (6, 9),
            (3, 12),
            (13, 14),
            (7, 11),
            (1, 2),
            (4, 8),
            // layer 6
            (1, 4),
            (7, 13),
            (2, 8),
            (11, 14),
            (5, 6),
            (9, 10),
            // layer 7
            (2, 4),
            (11, 13),
            (3, 8),
            (7, 12),
            // layer 8
            (6, 8),
            (10, 12),
            (3, 5),
            (7, 9),
            // layer 9
            (3, 4),
            (5, 6),
            (7, 8),
            (9, 10),
            (11, 12),
            // layer 10
            (6, 7),
            (8, 9)
        ],
        _ => return None,
    };
    Some(comps)
}

/// Sizes with a tabulated best network.
pub fn tabulated_sizes() -> &'static [usize] {
    &[1, 2, 3, 4, 5, 6, 7, 8, 16]
}
