//! Zero-one-principle verification.
//!
//! Knuth (TAOCP §5.3.4): a comparator network sorts all inputs iff it
//! sorts all binary inputs. We verify exhaustively over `2^n` bit
//! patterns, propagating each pattern through the network with bitwise
//! min/max on 0/1 values packed as `u8`s. This is the ground truth for
//! every network constructor in this crate (and for the Python side's
//! copies of the same tables, tested in `python/tests`).

use super::network::Network;

const MAX_EXHAUSTIVE_N: usize = 26;

fn sorts_pattern(net: &Network, pattern: u32) -> bool {
    let n = net.n();
    let mut v = [0u8; 64];
    for (b, slot) in v.iter_mut().enumerate().take(n) {
        *slot = ((pattern >> b) & 1) as u8;
    }
    for c in net.comparators() {
        let (i, j) = (c.i as usize, c.j as usize);
        let (a, b) = (v[i], v[j]);
        v[i] = a.min(b);
        v[j] = a.max(b);
    }
    v[..n].windows(2).all(|w| w[0] <= w[1])
}

/// Exhaustive zero-one check over all `2^n` binary inputs.
pub fn verify_zero_one(net: &Network) -> bool {
    let n = net.n();
    assert!(n <= MAX_EXHAUSTIVE_N, "n={n} too large for exhaustive zero-one check");
    (0u32..(1u32 << n)).all(|p| sorts_pattern(net, p))
}

/// Check the network sorts every binary input whose halves
/// `[0, split)` and `[split, n)` are individually sorted (i.e. it is a
/// valid *merging* network for that split). A sorted binary sequence of
/// length k is `0^(k-z) 1^z`, so there are only `(split+1)·(n-split+1)`
/// cases.
pub fn verify_merge(net: &Network, split: usize) -> bool {
    let n = net.n();
    assert!(split <= n);
    let lo_len = split;
    let hi_len = n - split;
    for z_lo in 0..=lo_len {
        for z_hi in 0..=hi_len {
            // 0^(lo_len-z_lo) 1^z_lo ++ 0^(hi_len-z_hi) 1^z_hi
            let mut pattern: u32 = 0;
            for b in (lo_len - z_lo)..lo_len {
                pattern |= 1 << b;
            }
            for b in (lo_len + hi_len - z_hi)..n {
                pattern |= 1 << b;
            }
            if !sorts_pattern(net, pattern) {
                return false;
            }
        }
    }
    true
}

/// Check the network sorts every *bitonic* binary input of the
/// asc⌢desc form `0^a 1^b 0^c` — the shape produced by reversing the
/// second of two sorted runs (how all our kernels feed bitonic
/// mergers).
pub fn verify_bitonic(net: &Network) -> bool {
    let n = net.n();
    for ones_start in 0..=n {
        for ones_end in ones_start..=n {
            let mut pattern: u32 = 0;
            for b in ones_start..ones_end {
                pattern |= 1 << b;
            }
            if !sorts_pattern(net, pattern) {
                return false;
            }
        }
    }
    true
}
