//! Network generators: the symmetric families (bitonic, Batcher
//! odd-even, Bose-Nelson) and the asymmetric `best` family (§2.3).

use super::best_tables;
use super::network::{Comparator, Network};

fn is_pow2(n: usize) -> bool {
    n != 0 && n & (n - 1) == 0
}

/// Full bitonic sorting network (Batcher 1968), `n` a power of two.
///
/// Iterative k/j form with *directional* comparators: inside a
/// descending sub-block the comparator routes min to the higher
/// address. Comparator count `(n/2)·log(n)·(log(n)+1)/2` — the paper's
/// Table 1 "Bitonic" column (80 at n=16, 240 at n=32).
pub fn bitonic_sort(n: usize) -> Network {
    assert!(is_pow2(n), "bitonic_sort requires power-of-two n, got {n}");
    let mut comps = Vec::new();
    let mut k = 2;
    while k <= n {
        let mut j = k / 2;
        while j > 0 {
            for i in 0..n {
                let l = i ^ j;
                if l > i {
                    if i & k == 0 {
                        comps.push(Comparator::new(i, l)); // ascending block
                    } else {
                        comps.push(Comparator::new(l, i)); // descending block
                    }
                }
            }
            j /= 2;
        }
        k *= 2;
    }
    Network::new(format!("bitonic-{n}"), n, comps)
}

/// Bitonic *merging* network: sorts any bitonic input of length `n`
/// (power of two). This is the half-cleaner cascade — `log(n)` layers
/// of `n/2` comparators each (Fig. 4 of the paper at n=32). Feed it
/// `ascending ⌢ reverse(ascending)` to merge two sorted runs.
pub fn bitonic_merge(n: usize) -> Network {
    assert!(is_pow2(n), "bitonic_merge requires power-of-two n, got {n}");
    let mut comps = Vec::new();
    let mut j = n / 2;
    while j > 0 {
        for i in 0..n {
            if i & j == 0 && (i % (2 * j)) < j {
                comps.push(Comparator::new(i, i + j));
            }
        }
        j /= 2;
    }
    Network::new(format!("bitonic-merge-{n}"), n, comps)
}

/// Batcher odd-even *mergesort* network, `n` a power of two.
/// Comparator count matches Table 1's "Odd-even" column (63 at n=16,
/// 191 at n=32).
pub fn odd_even_sort(n: usize) -> Network {
    assert!(is_pow2(n), "odd_even_sort requires power-of-two n, got {n}");
    let mut comps = Vec::new();
    oe_sort_rec(0, n, &mut comps);
    Network::new(format!("odd-even-{n}"), n, comps)
}

fn oe_sort_rec(lo: usize, n: usize, out: &mut Vec<Comparator>) {
    if n > 1 {
        let m = n / 2;
        oe_sort_rec(lo, m, out);
        oe_sort_rec(lo + m, m, out);
        oe_merge_rec(lo, n, 1, out);
    }
}

fn oe_merge_rec(lo: usize, n: usize, r: usize, out: &mut Vec<Comparator>) {
    let m = r * 2;
    if m < n {
        oe_merge_rec(lo, n, m, out);
        oe_merge_rec(lo + r, n, m, out);
        let mut i = lo + r;
        while i + r < lo + n {
            out.push(Comparator::new(i, i + r));
            i += m;
        }
    } else {
        out.push(Comparator::new(lo, lo + r));
    }
}

/// Batcher odd-even *merging* network for two sorted halves of an
/// `n`-channel input (split at `n/2`), `n` a power of two. Used to
/// build `best(32)` from two `best(16)` sorters (60+60+65 = 185, the
/// achievable end of Table 1's `135~185` asymmetric range).
pub fn odd_even_merge(n: usize) -> Network {
    assert!(is_pow2(n) && n >= 2);
    let mut comps = Vec::new();
    oe_merge_rec(0, n, 1, &mut comps);
    Network::new(format!("odd-even-merge-{n}"), n, comps)
}

/// Bose-Nelson network (1962), any `n ≥ 1`. Asymmetric, works for odd
/// sizes; matches the best counts at tiny n (5 at n=4, 19 at n=8) but
/// falls behind Batcher at n ≥ 16 (65 vs 63). Included as the third
/// family discussed by ref. [8] ("Engineering faster sorters").
pub fn bose_nelson(n: usize) -> Network {
    assert!(n >= 1);
    let mut comps = Vec::new();
    bn_split(0, n, &mut comps);
    Network::new(format!("bose-nelson-{n}"), n, comps)
}

fn bn_split(lo: usize, n: usize, out: &mut Vec<Comparator>) {
    if n > 1 {
        let m = n / 2;
        bn_split(lo, m, out);
        bn_split(lo + m, n - m, out);
        bn_merge(lo, m, lo + m, n - m, out);
    }
}

fn bn_merge(lo1: usize, n1: usize, lo2: usize, n2: usize, out: &mut Vec<Comparator>) {
    if n1 == 1 && n2 == 1 {
        out.push(Comparator::new(lo1, lo2));
    } else if n1 == 1 && n2 == 2 {
        out.push(Comparator::new(lo1, lo2 + 1));
        out.push(Comparator::new(lo1, lo2));
    } else if n1 == 2 && n2 == 1 {
        out.push(Comparator::new(lo1, lo2));
        out.push(Comparator::new(lo1 + 1, lo2));
    } else {
        let m1 = n1 / 2;
        // Bose-Nelson pairing: split so the odd halves line up.
        let m2 = if n1 % 2 == 1 { n2 / 2 } else { (n2 + 1) / 2 };
        bn_merge(lo1, m1, lo2, m2, out);
        bn_merge(lo1 + m1, n1 - m1, lo2 + m2, n2 - m2, out);
        bn_merge(lo1 + m1, n1 - m1, lo2, m2, out);
    }
}

/// The asymmetric **best-known** sorting network for `n` channels —
/// the paper's §2.3 choice for column sort:
///
/// * `n ≤ 16`: hand-verified optimal/best-known tables
///   ([Gamble's generator][g], Knuth TAOCP §5.3.4) — 60 comparators at
///   `n = 16` vs 63 (odd-even) / 80 (bitonic).
/// * `n = 32`: constructed as two `best(16)` + Batcher 32-merge = 185,
///   the best-known count when the paper was written (Table 1 upper
///   bound of the `135~185` range; 135 is the proven lower bound).
/// * other `n`: falls back to [`bose_nelson`] (still asymmetric and
///   valid, just not best-known).
///
/// [g]: http://pages.ripco.net/~jgamble/nw.html
pub fn best(n: usize) -> Network {
    if let Some(comps) = best_tables::table(n) {
        return Network::new(format!("best-{n}"), n, comps);
    }
    if n == 32 {
        let half = best(16);
        return half
            .offset(0, 32)
            .then(&half.offset(16, 32))
            .then(&odd_even_merge(32));
    }
    bose_nelson(n)
}

/// Sizes for which [`best`] has a hand-verified table (re-exported
/// from the table module for sweeps).
pub fn tabulated_best_sizes() -> &'static [usize] {
    best_tables::tabulated_sizes()
}

/// All three Table 1 families for one input size.
pub fn table1_families(n: usize) -> [Network; 3] {
    [bitonic_sort(n), odd_even_sort(n), best(n)]
}
