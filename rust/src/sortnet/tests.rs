use super::gen;
use super::network::{Comparator, Network};
use crate::simd::{Vector, V128, V256};
use crate::testutil::{forall, Rng};

#[test]
fn bitonic_sort_counts_match_table1() {
    // Paper Table 1, "Bitonic" column.
    assert_eq!(gen::bitonic_sort(4).size(), 6);
    assert_eq!(gen::bitonic_sort(8).size(), 24);
    assert_eq!(gen::bitonic_sort(16).size(), 80);
    assert_eq!(gen::bitonic_sort(32).size(), 240);
}

#[test]
fn odd_even_counts_match_table1() {
    // Paper Table 1, "Odd-even" column.
    assert_eq!(gen::odd_even_sort(4).size(), 5);
    assert_eq!(gen::odd_even_sort(8).size(), 19);
    assert_eq!(gen::odd_even_sort(16).size(), 63);
    assert_eq!(gen::odd_even_sort(32).size(), 191);
}

#[test]
fn best_counts_match_table1_asymmetric_column() {
    // Paper Table 1, "Asymmetric Network" column: 5, 19, 55~60, 135~185.
    assert_eq!(gen::best(4).size(), 5);
    assert_eq!(gen::best(8).size(), 19);
    let b16 = gen::best(16).size();
    assert!((55..=60).contains(&b16), "best-16 = {b16}");
    let b32 = gen::best(32).size();
    assert!((135..=185).contains(&b32), "best-32 = {b32}");
}

#[test]
fn best_16_is_greens_60() {
    let n = gen::best(16);
    assert_eq!(n.size(), 60);
    assert_eq!(n.depth(), 10, "Green's network has depth 10");
}

#[test]
fn tabulated_best_sizes_all_verify() {
    for &n in crate::sortnet::gen::tabulated_best_sizes() {
        assert!(gen::best(n).verify_zero_one(), "tabulated best-{n}");
    }
}

#[test]
fn all_sorters_pass_zero_one() {
    for n in [2usize, 4, 8, 16] {
        assert!(gen::bitonic_sort(n).verify_zero_one(), "bitonic-{n}");
        assert!(gen::odd_even_sort(n).verify_zero_one(), "odd-even-{n}");
    }
    for n in 1..=16usize {
        assert!(gen::best(n).verify_zero_one(), "best-{n}");
        assert!(gen::bose_nelson(n).verify_zero_one(), "bose-nelson-{n}");
    }
}

#[test]
#[ignore = "2^32-free but still ~30s in debug; run with --ignored"]
fn large_sorters_pass_zero_one() {
    assert!(gen::bitonic_sort(32).verify_zero_one(), "bitonic-32");
}

#[test]
fn best_32_sorts_zero_one_subsampled() {
    // Full 2^32 enumeration is infeasible; best-32 is built from two
    // verified best-16 sorters + a verified odd-even merge, so check
    // the merge property + random inputs instead.
    let n = gen::best(32);
    assert_eq!(n.size(), 185);
    let oe = gen::odd_even_merge(32);
    assert!(oe.verify_merge(16), "odd-even-merge-32 merges 16+16");
    forall(200, |rng: &mut Rng| {
        let mut data: Vec<u32> = (0..32).map(|_| rng.next_u32() % 64).collect();
        let mut expect = data.clone();
        expect.sort_unstable();
        n.apply_slice(&mut data);
        assert_eq!(data, expect);
    });
}

#[test]
fn merging_networks_verify() {
    for n in [2usize, 4, 8, 16, 32] {
        assert!(gen::odd_even_merge(n).verify_merge(n / 2), "oe-merge-{n}");
        assert!(gen::bitonic_merge(n).verify_bitonic_merge(), "bitonic-merge-{n}");
    }
}

#[test]
fn bitonic_merge_structure() {
    // log(n) layers of n/2 comparators each (Fig. 4 at n=32).
    for n in [4usize, 8, 16, 32] {
        let m = gen::bitonic_merge(n);
        let lg = n.trailing_zeros() as usize;
        assert_eq!(m.size(), lg * n / 2);
        assert_eq!(m.depth(), lg);
        assert_eq!(m.layers().len(), lg);
        for layer in m.layers() {
            assert_eq!(layer.len(), n / 2, "each half-cleaner layer is n/2 wide");
        }
    }
}

#[test]
fn bitonic_merge_merges_reversed_second_run() {
    forall(300, |rng: &mut Rng| {
        let k = [2usize, 4, 8, 16][rng.below(4)];
        let mut a: Vec<i32> = (0..k).map(|_| rng.next_i32() % 1000).collect();
        let mut b: Vec<i32> = (0..k).map(|_| rng.next_i32() % 1000).collect();
        a.sort_unstable();
        b.sort_unstable();
        let mut input = a.clone();
        input.extend(b.iter().rev()); // asc ⌢ desc = bitonic
        let mut expect = input.clone();
        expect.sort_unstable();
        gen::bitonic_merge(2 * k).apply_slice(&mut input);
        assert_eq!(input, expect);
    });
}

#[test]
fn apply_columns_sorts_each_lane() {
    // Column application over V128s sorts all four lanes independently
    // — property checked against the scalar oracle for every family.
    forall(200, |rng: &mut Rng| {
        let r = [4usize, 8, 16][rng.below(3)];
        let net = gen::best(r);
        let mut regs: Vec<V128<i32>> = (0..r)
            .map(|_| {
                V128([
                    rng.next_i32() % 100,
                    rng.next_i32() % 100,
                    rng.next_i32() % 100,
                    rng.next_i32() % 100,
                ])
            })
            .collect();
        let mut lanes: Vec<Vec<i32>> =
            (0..4).map(|l| regs.iter().map(|v| v.lane(l)).collect()).collect();
        net.apply_columns(&mut regs);
        for (l, lane) in lanes.iter_mut().enumerate() {
            lane.sort_unstable();
            let got: Vec<i32> = regs.iter().map(|v| v.lane(l)).collect();
            assert_eq!(&got, lane, "lane {l} sorted");
        }
    });
}

#[test]
fn apply_columns_sorts_each_lane_v256() {
    // The width-generic column application: the same comparator
    // stream sorts all 8 V256 lanes independently.
    forall(100, |rng: &mut Rng| {
        let r = [8usize, 16][rng.below(2)];
        let net = gen::best(r);
        let mut regs: Vec<V256<i32>> = (0..r)
            .map(|_| {
                let vals: [i32; 8] = std::array::from_fn(|_| rng.next_i32() % 100);
                V256::load(&vals)
            })
            .collect();
        let mut lanes: Vec<Vec<i32>> =
            (0..8).map(|l| regs.iter().map(|v| Vector::lane(*v, l)).collect()).collect();
        net.apply_columns(&mut regs);
        for (l, lane) in lanes.iter_mut().enumerate() {
            lane.sort_unstable();
            let got: Vec<i32> = regs.iter().map(|v| Vector::lane(*v, l)).collect();
            assert_eq!(&got, lane, "V256 lane {l} sorted");
        }
    });
}

#[test]
fn depth_and_layers_agree() {
    for net in [gen::bitonic_sort(16), gen::odd_even_sort(16), gen::best(16)] {
        assert_eq!(net.depth(), net.layers().len(), "{}", net.name());
        let total: usize = net.layers().iter().map(|l| l.len()).sum();
        assert_eq!(total, net.size());
        // No channel touched twice within a layer.
        for layer in net.layers() {
            let mut seen = std::collections::HashSet::new();
            for c in layer {
                assert!(seen.insert(c.i), "channel {} reused in layer", c.i);
                assert!(seen.insert(c.j), "channel {} reused in layer", c.j);
            }
        }
    }
}

#[test]
fn offset_and_then_compose() {
    let b8 = gen::best(8);
    let two = b8.offset(0, 16).then(&b8.offset(8, 16)).then(&gen::odd_even_merge(16));
    assert!(two.verify_zero_one(), "composed 8+8 sorter");
    assert_eq!(two.size(), 19 + 19 + gen::odd_even_merge(16).size());
}

#[test]
fn apply_slice_sorts_random_inputs_all_families() {
    forall(300, |rng: &mut Rng| {
        let n = [4usize, 8, 16][rng.below(3)];
        let nets = gen::table1_families(n);
        let data: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let mut expect = data.clone();
        expect.sort_unstable();
        for net in &nets {
            let mut d = data.clone();
            net.apply_slice(&mut d);
            assert_eq!(d, expect, "{}", net.name());
        }
    });
}

#[test]
fn apply_slice_f32() {
    let net = gen::best(8);
    let mut d = [3.5f32, -1.0, 0.0, 7.25, -6.5, 2.0, 2.0, -0.5];
    net.apply_slice(&mut d);
    assert_eq!(d, [-6.5, -1.0, -0.5, 0.0, 2.0, 2.0, 3.5, 7.25]);
}

#[test]
#[should_panic(expected = "out of range")]
fn network_rejects_out_of_range_comparator() {
    Network::new("bad", 4, vec![Comparator::new(0, 4)]);
}

#[test]
#[should_panic(expected = "length mismatch")]
fn apply_slice_rejects_wrong_length() {
    gen::best(8).apply_slice(&mut [1u32, 2, 3]);
}

#[test]
fn bose_nelson_any_n_sorts() {
    for n in 1..=12usize {
        assert!(gen::bose_nelson(n).verify_zero_one(), "bose-nelson-{n}");
    }
}

#[test]
fn apply_columns_sorts_each_lane_w2_64bit() {
    // Column application at W = 2 (V128D / V256D): the network's
    // comparator stream is lane-count-agnostic, so the same code must
    // sort two 64-bit columns (or four, at V256D) independently —
    // property-checked against the apply_slice scalar oracle.
    use crate::simd::{V128D, V256D};
    forall(200, |rng: &mut Rng| {
        let r = [4usize, 8, 16][rng.below(3)];
        let net = gen::best(r);
        let mut regs: Vec<V128D<u64>> =
            (0..r).map(|_| V128D([rng.next_u64() % 100, rng.next_u64() % 100])).collect();
        let mut lanes: Vec<Vec<u64>> =
            (0..2).map(|l| regs.iter().map(|v| v.lane(l)).collect()).collect();
        net.apply_columns(&mut regs);
        for (l, lane) in lanes.iter_mut().enumerate() {
            net.apply_slice(lane);
            let got: Vec<u64> = regs.iter().map(|v| v.lane(l)).collect();
            assert_eq!(&got, lane, "V128D column {l} of best-{r}");
        }
    });
    forall(100, |rng: &mut Rng| {
        let r = [8usize, 16][rng.below(2)];
        let net = gen::best(r);
        let mut regs: Vec<V256D<u64>> = (0..r)
            .map(|_| {
                let vals: [u64; 4] = std::array::from_fn(|_| rng.next_u64() % 100);
                V256D::load(&vals)
            })
            .collect();
        let mut lanes: Vec<Vec<u64>> =
            (0..4).map(|l| regs.iter().map(|v| Vector::lane(*v, l)).collect()).collect();
        net.apply_columns(&mut regs);
        for (l, lane) in lanes.iter_mut().enumerate() {
            net.apply_slice(lane);
            let got: Vec<u64> = regs.iter().map(|v| Vector::lane(*v, l)).collect();
            assert_eq!(&got, lane, "V256D column {l} of best-{r}");
        }
    });
}

#[test]
fn apply_columns_zero_one_w2() {
    // Zero-one principle per 64-bit column: every 0/1 pattern of both
    // columns of an R=4 register file, exhaustively (16 × 16 grids).
    use crate::simd::V128D;
    let net = gen::best(4);
    for bits0 in 0..16u64 {
        for bits1 in 0..16u64 {
            let mut regs: Vec<V128D<u64>> =
                (0..4).map(|i| V128D([(bits0 >> i) & 1, (bits1 >> i) & 1])).collect();
            net.apply_columns(&mut regs);
            for l in 0..2 {
                let col: Vec<u64> = regs.iter().map(|v| v.lane(l)).collect();
                let mut expect = col.clone();
                expect.sort_unstable();
                assert_eq!(col, expect, "bits=({bits0:04b},{bits1:04b}) col {l}");
            }
        }
    }
}
