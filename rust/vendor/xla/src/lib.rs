//! Offline stub of the `xla` (xla-rs) PJRT surface used by neonms.
//!
//! The build environment has no native XLA/PJRT plugin, so this
//! vendored crate provides the exact type/method surface
//! `neonms::runtime` compiles against while reporting "runtime
//! unavailable" at the single entry point ([`PjRtClient::cpu`] /
//! [`HloModuleProto::from_text_file`]). The neonms coordinator and
//! runtime already treat PJRT startup failure as a first-class
//! degraded mode (CPU-only sorting, XLA tests skip), so swapping this
//! stub for the real crate is a Cargo.toml-only change.
//!
//! Types that can only be obtained from a successful client
//! construction hold an uninhabited `Void`, making their methods
//! statically unreachable rather than `unimplemented!()`.

use std::fmt;
use std::path::Path;

/// Stub error type; implements `std::error::Error` so callers'
/// `anyhow` contexts and `?` conversions work unchanged.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Crate-local result alias, as in xla-rs.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT native runtime is not available in this offline build \
         (vendored stub); point Cargo.toml at the real `xla` crate to enable offload"
    ))
}

/// Uninhabited marker: values of types wrapping this can never exist.
enum Void {}

/// Marker for element types PJRT literals can hold.
pub trait NativeType: Copy {}
impl NativeType for i32 {}
impl NativeType for u32 {}
impl NativeType for i64 {}
impl NativeType for u64 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// Marker for element types arrays can be read back as.
pub trait ArrayElement: Copy {}
impl ArrayElement for i32 {}
impl ArrayElement for u32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u64 {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}

/// PJRT client handle. Unconstructible in the stub: [`PjRtClient::cpu`]
/// always reports the runtime as unavailable.
pub struct PjRtClient(Void);

impl PjRtClient {
    /// Create the CPU PJRT client — always `Err` in the stub.
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Backend platform name.
    pub fn platform_name(&self) -> String {
        match self.0 {}
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.0 {}
    }
}

/// Parsed HLO module. Unconstructible in the stub.
pub struct HloModuleProto(Void);

impl HloModuleProto {
    /// Parse an HLO text file — always `Err` in the stub.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from a parsed module.
pub struct XlaComputation(Void);

impl XlaComputation {
    /// Wrap a parsed module (statically unreachable in the stub).
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        match proto.0 {}
    }
}

/// A compiled, device-loaded executable. Unconstructible in the stub.
pub struct PjRtLoadedExecutable(Void);

impl PjRtLoadedExecutable {
    /// Execute on device buffers/literals.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }
}

/// A device buffer. Unconstructible in the stub.
pub struct PjRtBuffer(Void);

impl PjRtBuffer {
    /// Copy device data back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.0 {}
    }
}

/// A host literal. Constructible (inputs are staged host-side before
/// any client exists), but device-derived reads always error.
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Unwrap a 1-tuple result literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    /// Read the literal back as a typed vector.
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}
