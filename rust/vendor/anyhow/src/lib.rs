//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline build environment has no crates.io access, so this
//! vendored shim provides exactly the API subset the workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Semantics match anyhow
//! where it matters for callers: contexts stack outermost-first, `?`
//! converts any `std::error::Error`, and `{:#}`/`{:?}` render the
//! full cause chain.

use std::fmt;

/// `Result<T, Error>` with the error type defaulted, as in anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a message plus a chain of causes.
///
/// Deliberately does **not** implement `std::error::Error` (mirroring
/// anyhow), which is what makes the blanket `From<E: Error>` and the
/// [`Context`] impls coherent.
pub struct Error {
    /// `chain[0]` is the outermost context; the last entry is the
    /// root cause.
    chain: Vec<String>,
}

impl Error {
    /// Create from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod private {
    /// Converts any supported error payload into [`crate::Error`] —
    /// implemented for std errors and for `Error` itself so contexts
    /// stack. Coherent because `Error` never implements
    /// `std::error::Error` (and, being local, no other crate can add
    /// that impl).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// results and options, mirroring anyhow's `Context`.
pub trait Context<T>: Sized {
    /// Wrap the error with a context message.
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
    ($fmt:literal, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Io;
    impl fmt::Display for Io {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "io oops")
        }
    }
    impl std::error::Error for Io {}

    #[test]
    fn context_stacks_outermost_first() {
        let e: Result<()> = std::result::Result::<(), Io>::Err(Io).context("outer");
        let err = e.unwrap_err();
        assert_eq!(format!("{err}"), "outer");
        assert_eq!(format!("{err:#}"), "outer: io oops");
        assert_eq!(err.root_cause(), "io oops");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32> = std::result::Result::<u32, Io>::Ok(7)
            .with_context(|| -> String { unreachable!("must not evaluate on Ok") });
        assert_eq!(ok.unwrap(), 7);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert_eq!(f(50).unwrap_err().to_string(), "too big: 50");
        assert_eq!(anyhow!("plain {}", 7).to_string(), "plain 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(Io)?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
