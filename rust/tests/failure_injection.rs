//! Failure-injection and robustness tests: wrong inputs, hostile
//! configurations, overload, and resource boundaries — the service
//! must degrade predictably, never corrupt data.

use neonms::coordinator::{CoordinatorConfig, SortService};
use neonms::runtime::ArtifactRegistry;
use neonms::sort::{NeonMergeSort, ParallelNeonMergeSort};
use neonms::testutil::{assert_sorted, Rng};

#[test]
fn registry_tolerates_garbage_artifacts() {
    let dir = std::env::temp_dir().join(format!("neonms_bad_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // Well-named but corrupt file: scanning succeeds, compiling fails.
    std::fs::write(dir.join("block_sort_int32_1024.hlo.txt"), "not hlo at all").unwrap();
    let reg = ArtifactRegistry::scan(&dir);
    assert_eq!(reg.len(), 1);
    // Service startup must surface the failure as Err, not panic/hang.
    let cfg = CoordinatorConfig { xla_cutoff: Some(1024), ..Default::default() };
    let res = SortService::start(cfg, Some(dir.clone()));
    assert!(res.is_err(), "corrupt artifact must fail startup explicitly");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn service_without_artifacts_dir_still_serves() {
    let cfg = CoordinatorConfig { xla_cutoff: Some(1024), ..Default::default() };
    let svc = SortService::start(cfg, Some("/definitely/not/here".into())).unwrap();
    assert!(!svc.xla_enabled(), "missing dir disables offload silently");
    let h = svc.submit(vec![3, 1, 2]);
    assert_eq!(h.wait().unwrap(), vec![1, 2, 3]);
    svc.shutdown();
}

#[test]
fn overload_queue_never_exceeds_capacity() {
    let cfg = CoordinatorConfig { workers: 0, queue_capacity: 8, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    let mut accepted = 0;
    for _ in 0..100 {
        if svc.try_submit(vec![1, 2]).is_ok() {
            accepted += 1;
        }
    }
    assert_eq!(accepted, 8, "capacity is a hard bound");
    assert_eq!(svc.metrics().rejected, 92);
    svc.shutdown();
}

#[test]
fn concurrent_submitters_all_complete() {
    let svc = std::sync::Arc::new(SortService::start_default().unwrap());
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let svc = std::sync::Arc::clone(&svc);
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            for _ in 0..25 {
                let len = rng.below(3000);
                let data = rng.vec_u32(len);
                let mut expect = data.clone();
                expect.sort_unstable();
                assert_eq!(svc.submit(data).wait().unwrap(), expect);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = svc.metrics();
    assert_eq!(m.submitted, 100);
    assert_eq!(m.completed, 100);
    std::sync::Arc::into_inner(svc).unwrap().shutdown();
}

#[test]
fn dropped_handle_does_not_wedge_workers() {
    let svc = SortService::start_default().unwrap();
    for _ in 0..16 {
        let _ = svc.submit(vec![5, 4, 3, 2, 1]); // handle dropped immediately
    }
    // Service stays healthy for a live request afterwards.
    let h = svc.submit(vec![9, 8, 7]);
    assert_eq!(h.wait().unwrap(), vec![7, 8, 9]);
    svc.shutdown();
}

#[test]
fn parallel_sort_with_more_threads_than_data() {
    let mut rng = Rng::new(3);
    let data = rng.vec_u32(5000);
    let mut v = data.clone();
    ParallelNeonMergeSort::with_threads(64).sort(&mut v);
    assert_sorted(&v, "T=64 over 5000 elements");
}

#[test]
fn extreme_values_and_degenerate_distributions() {
    let s = NeonMergeSort::paper_default();
    let cases: Vec<Vec<u32>> = vec![
        vec![u32::MAX; 1000],
        vec![0; 1000],
        (0..1000).map(|i| if i % 2 == 0 { 0 } else { u32::MAX }).collect(),
        vec![u32::MAX, 0, u32::MAX, 0, 1, u32::MAX - 1],
    ];
    for data in cases {
        let mut v = data.clone();
        let mut expect = data;
        expect.sort_unstable();
        s.sort(&mut v);
        assert_eq!(v, expect);
    }
}

#[test]
fn f32_infinities_sort_to_the_ends() {
    let s = NeonMergeSort::paper_default();
    let mut v = vec![1.0f32, f32::NEG_INFINITY, 0.0, f32::INFINITY, -2.5, 1e38, -1e38];
    // Pad to a vector-friendly length with finite values.
    v.extend((0..57).map(|i| i as f32));
    let mut expect = v.clone();
    expect.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s.sort(&mut v);
    assert_eq!(v, expect);
}

#[test]
fn shutdown_under_load_completes_everything_accepted() {
    let svc = SortService::start(
        CoordinatorConfig { workers: 2, ..Default::default() },
        None,
    )
    .unwrap();
    let mut rng = Rng::new(4);
    let handles: Vec<_> = (0..40).map(|_| svc.submit(rng.vec_u32(10_000))).collect();
    svc.shutdown(); // races the queue drain deliberately
    for h in handles {
        assert_sorted(&h.wait().unwrap(), "post-shutdown completion");
    }
}
