//! Cross-module integration tests: the public API exercised the way
//! the examples and the coordinator use it, including the XLA runtime
//! path when artifacts are present.

use neonms::baselines::{blocksort, introsort};
use neonms::bench::Workload;
use neonms::coordinator::{CoordinatorConfig, SortService};
use neonms::kernels::inregister::InRegisterSorter;
use neonms::kernels::runmerge::RunMerger;
use neonms::runtime::ArtifactRegistry;
use neonms::sort::{NeonMergeSort, ParallelNeonMergeSort};
use neonms::sortnet::gen;
use neonms::testutil::{assert_permutation, assert_sorted, Rng};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn all_sorters_agree_across_workloads_and_sizes() {
    let neon = NeonMergeSort::paper_default();
    let par = ParallelNeonMergeSort::with_threads(3);
    for w in Workload::all() {
        for n in [0usize, 1, 63, 64, 65, 1000, 65_536, 200_000] {
            let data = w.generate(n, 1234);
            let mut expect = data.clone();
            expect.sort_unstable();
            let mut a = data.clone();
            neon.sort(&mut a);
            assert_eq!(a, expect, "neon-ms {} n={n}", w.name());
            let mut b = data.clone();
            par.sort(&mut b);
            assert_eq!(b, expect, "parallel {} n={n}", w.name());
            let mut c = data.clone();
            introsort::sort(&mut c);
            assert_eq!(c, expect, "introsort {} n={n}", w.name());
            let mut d = data.clone();
            blocksort::sort(&mut d);
            assert_eq!(d, expect, "blocksort {} n={n}", w.name());
        }
    }
}

#[test]
fn sort_pipeline_composes_from_kernels() {
    // Manually chain the three stages the full sort uses and verify
    // against the integrated path — catches stage-contract drift.
    let mut rng = Rng::new(9);
    let data = rng.vec_u32(64 * 37); // multiple of 64
    let inreg = InRegisterSorter::paper_default();
    let merger = RunMerger::paper_default();

    let mut manual = data.clone();
    let mut run = inreg.sort_runs(&mut manual);
    let n = manual.len();
    let mut aux = vec![0u32; n];
    let mut in_data = true;
    while run < n {
        {
            let (src, dst): (&[u32], &mut [u32]) =
                if in_data { (&manual, &mut aux) } else { (&aux, &mut manual) };
            let mut base = 0;
            while base < n {
                let mid = (base + run).min(n);
                let end = (base + 2 * run).min(n);
                if mid < end {
                    merger.merge(&src[base..mid], &src[mid..end], &mut dst[base..end]);
                } else {
                    dst[base..end].copy_from_slice(&src[base..end]);
                }
                base = end;
            }
        }
        in_data = !in_data;
        run *= 2;
    }
    if !in_data {
        manual.copy_from_slice(&aux);
    }

    let mut integrated = data.clone();
    NeonMergeSort::paper_default().sort(&mut integrated);
    assert_eq!(manual, integrated);
}

#[test]
fn network_library_feeds_kernels_consistently() {
    // The in-register sorter must use exactly the advertised network.
    let s = InRegisterSorter::paper_default();
    assert_eq!(s.network().size(), gen::best(16).size());
    assert_eq!(s.network().size(), 60);
    // And the network itself is valid.
    assert!(s.network().verify_zero_one());
}

#[test]
fn service_over_every_route_returns_oracle_results() {
    let reg = ArtifactRegistry::scan(artifacts_dir());
    let cfg = CoordinatorConfig {
        workers: 2,
        tiny_cutoff: 64,
        parallel_cutoff: 1 << 20,
        xla_cutoff: (!reg.is_empty()).then_some(4096),
        ..Default::default()
    };
    let svc =
        SortService::start(cfg, (!reg.is_empty()).then(artifacts_dir)).expect("service");
    let mut rng = Rng::new(5);
    let mut cases = Vec::new();
    for len in [5usize, 100, 5000, 8192, 1 << 20] {
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        expect.sort_unstable();
        cases.push((svc.submit(data), expect));
    }
    for (h, expect) in cases {
        assert_eq!(h.wait().unwrap(), expect);
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 5);
    assert!(m.route_tiny >= 1 && m.route_single >= 1 && m.route_parallel >= 1);
    if svc.xla_enabled() {
        assert!(m.route_xla >= 1, "xla route not exercised");
    }
    svc.shutdown();
}

#[test]
fn sharded_batched_service_end_to_end() {
    // The PR-1 coordinator shape end to end: 4 shards, stealing, and
    // the fused dynamic batcher, under a mixed burst from two
    // submitter threads. Every reply must equal sort_unstable and the
    // occupancy metric must show real coalescing.
    let cfg = CoordinatorConfig {
        workers: 1,
        shards: 4,
        batch_max: 16,
        ..Default::default()
    };
    let svc = std::sync::Arc::new(SortService::start(cfg, None).expect("service"));
    // A large job first pins the lone worker so the burst of small
    // jobs queues up across all shards behind it.
    let mut rng = Rng::new(77);
    let big = svc.submit(rng.vec_u32(2 << 20));
    let mut joins = Vec::new();
    for t in 0..2u64 {
        let svc = std::sync::Arc::clone(&svc);
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(200 + t);
            (0..40usize)
                .map(|i| {
                    let len = [5usize, 80, 900, 3000][i % 4] + rng.below(11);
                    let data = rng.vec_u32(len);
                    let mut expect = data.clone();
                    expect.sort_unstable();
                    (svc.submit(data), expect)
                })
                .collect::<Vec<_>>()
        }));
    }
    for j in joins {
        for (h, expect) in j.join().unwrap() {
            assert_eq!(h.wait().unwrap(), expect);
        }
    }
    assert_sorted(&big.wait().unwrap(), "big");
    let m = svc.metrics();
    assert_eq!(m.completed, 81);
    assert_eq!(m.shard_depths.len(), 4);
    assert!(m.batches >= 1, "mixed burst should fuse ≥1 batch");
    assert!(m.batch_occupancy >= 2.0, "occupancy {} < 2", m.batch_occupancy);
    assert!(m.steals >= 1, "lone worker must have stolen from sibling shards");
    std::sync::Arc::into_inner(svc).unwrap().shutdown();
}

#[test]
fn xla_block_sort_matches_native_sort() {
    let reg = ArtifactRegistry::scan(artifacts_dir());
    if reg.is_empty() {
        eprintln!("SKIP: run `make artifacts` for the XLA integration test");
        return;
    }
    use neonms::runtime::{BlockSorter, PjrtRuntime};
    let rt = std::sync::Arc::new(PjrtRuntime::cpu().unwrap());
    let bs = BlockSorter::new(rt, &reg).unwrap();
    let mut rng = Rng::new(6);
    let data: Vec<i32> = (0..10_000).map(|_| rng.next_i32()).collect();
    let mut via_xla = data.clone();
    bs.sort_i32(&mut via_xla).unwrap();
    let mut via_native = data
        .iter()
        .map(|&x| (x as i64 + i32::MAX as i64 + 1) as u32)
        .collect::<Vec<u32>>();
    NeonMergeSort::paper_default().sort(&mut via_native);
    let via_native: Vec<i32> =
        via_native.iter().map(|&x| (x as i64 - i32::MAX as i64 - 1) as i32).collect();
    assert_eq!(via_xla, via_native, "XLA path and native path disagree");
}

#[test]
fn mergepath_partition_drives_parallel_merge_correctly() {
    // The exact composition the parallel sorter performs, done by hand.
    let mut rng = Rng::new(7);
    let mut a = rng.vec_u32(10_000);
    let mut b = rng.vec_u32(14_000);
    a.sort_unstable();
    b.sort_unstable();
    let merger = RunMerger::paper_default();
    let mut out = vec![0u32; a.len() + b.len()];
    for seg in neonms::mergepath::partition(&a, &b, 7) {
        let end = seg.out_lo + seg.out_len();
        merger.merge(
            &a[seg.a_lo..seg.a_hi],
            &b[seg.b_lo..seg.b_hi],
            &mut out[seg.out_lo..end],
        );
    }
    assert_sorted(&out, "partitioned parallel merge");
    let all: Vec<u32> = a.iter().chain(b.iter()).copied().collect();
    assert_permutation(&out, &all, "partitioned parallel merge");
}

#[test]
fn f32_and_i32_end_to_end() {
    let s = NeonMergeSort::paper_default();
    let mut rng = Rng::new(8);
    let mut vi: Vec<i32> = (0..100_000).map(|_| rng.next_i32()).collect();
    let mut expect = vi.clone();
    expect.sort_unstable();
    s.sort(&mut vi);
    assert_eq!(vi, expect);
    let mut vf: Vec<f32> = (0..100_000).map(|_| rng.next_f32() * 1e6 - 5e5).collect();
    s.sort(&mut vf);
    assert_sorted(&vf, "f32 100K");
}
