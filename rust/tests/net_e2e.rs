//! End-to-end wire protocol tests: a real [`NetServer`] on a loopback
//! socket in front of a live [`SortService`], driven by [`WireClient`]s
//! over actual TCP. Covers the full request/response surface, the
//! Busy → `RETRY_AFTER` mapping (hint and all), abrupt-disconnect
//! drop-to-cancel, and a multi-connection soak under seeded fault
//! injection with the per-tenant accounting identity checked across
//! the wire.

use neonms::coordinator::{BusyReason, CoordinatorConfig, ElemBuf, FaultPlan, SortService};
use neonms::net::{
    codec, NetError, NetServer, PollOutcome, Request, SubmitOutcome, WireBusyReason, WireClient,
};
use neonms::simd::KeyValue;
use neonms::testutil::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Boot a service + server pair on an OS-assigned loopback port.
fn serve(cfg: CoordinatorConfig) -> (Arc<SortService>, NetServer) {
    let svc = Arc::new(SortService::start(cfg, None).unwrap());
    let server = NetServer::bind(Arc::clone(&svc), "127.0.0.1:0").unwrap();
    (svc, server)
}

fn is_sorted(buf: &ElemBuf) -> bool {
    match buf {
        ElemBuf::U32(v) => v.windows(2).all(|w| w[0] <= w[1]),
        ElemBuf::U64(v) => v.windows(2).all(|w| w[0] <= w[1]),
        ElemBuf::Pair(v) => v.windows(2).all(|w| w[0] <= w[1]),
    }
}

#[test]
fn loopback_full_protocol_flow() {
    let (svc, server) = serve(CoordinatorConfig::default());
    let mut c = WireClient::connect(server.local_addr()).unwrap();

    // SUBMIT before HELLO: a semantic error answered in-band — the
    // connection survives it.
    match c.submit(ElemBuf::U32(vec![3, 1, 2])) {
        Err(NetError::Remote(msg)) => assert!(msg.contains("HELLO"), "got: {msg}"),
        other => panic!("expected a remote protocol error, got {other:?}"),
    }

    // Handshake: weight 0 is clamped to 1 service-side and the
    // effective config is echoed back.
    let (weight, burst) = c.hello("wire-1", 0, 4 << 20).unwrap();
    assert_eq!(weight, 1, "service clamps weight 0 to 1");
    assert_eq!(burst, 4 << 20);

    // One submit per element kind, each checked against the sort
    // oracle after travelling the wire both ways.
    let mut rng = Rng::new(0xE2E);
    let u32s = rng.vec_u32(4000);
    let u64s = rng.vec_u64(3000);
    let pairs: Vec<KeyValue> =
        (0..2000).map(|i| KeyValue::new(rng.next_u32(), i as u32)).collect();
    for (input, label) in [
        (ElemBuf::U32(u32s.clone()), "u32"),
        (ElemBuf::U64(u64s.clone()), "u64"),
        (ElemBuf::Pair(pairs.clone()), "pair"),
    ] {
        let want = match input.clone() {
            ElemBuf::U32(mut v) => {
                v.sort_unstable();
                ElemBuf::U32(v)
            }
            ElemBuf::U64(mut v) => {
                v.sort_unstable();
                ElemBuf::U64(v)
            }
            ElemBuf::Pair(mut v) => {
                v.sort_unstable();
                ElemBuf::Pair(v)
            }
        };
        let SubmitOutcome::Accepted { id } = c.submit(input).unwrap() else {
            panic!("{label}: default service must not shed a lone submit");
        };
        let got = c.wait(id).unwrap().unwrap_or_else(|e| panic!("{label} failed: {e}"));
        assert_eq!(got.kind(), want.kind(), "{label}: element kind survives the wire");
        assert_eq!(got, want, "{label}: result must match the oracle");
    }

    // Reusing an id that is still in flight is a semantic error; the
    // original request is unharmed and still polls to completion.
    let SubmitOutcome::Accepted { id: big_id } =
        c.submit(ElemBuf::U32(rng.vec_u32(500_000))).unwrap()
    else {
        panic!("big submit shed");
    };
    let dup = codec::encode_request(&Request::Submit {
        id: big_id,
        data: ElemBuf::U32(vec![1]),
    })
    .unwrap();
    c.send_raw(&dup).unwrap();
    match c.recv().unwrap() {
        neonms::net::Response::ProtoError { message } => {
            assert!(message.contains("in-flight id"), "got: {message}");
        }
        other => panic!("duplicate id must be refused, got {other:?}"),
    }
    assert!(is_sorted(&c.wait(big_id).unwrap().unwrap()), "original request unharmed");

    // POLL for an id this connection never submitted.
    match c.poll(9999) {
        Err(NetError::Remote(msg)) => assert!(msg.contains("unknown"), "got: {msg}"),
        other => panic!("expected a remote protocol error, got {other:?}"),
    }

    // CANCEL a fresh submit, then CANCEL it again: idempotent acks.
    let SubmitOutcome::Accepted { id } = c.submit(ElemBuf::U32(rng.vec_u32(100_000))).unwrap()
    else {
        panic!("cancel-target submit shed");
    };
    c.cancel(id).unwrap();
    c.cancel(id).unwrap();

    // METRICS over the wire reflects this connection's work.
    let m = c.metrics().unwrap();
    assert!(m.connections_open >= 1, "we are connected: {}", m.connections_open);
    assert!(m.net_frames > 8, "every request above was counted: {}", m.net_frames);
    assert_eq!(m.net_protocol_errors, 0, "semantic errors are not stream errors");
    let t = m
        .tenants
        .iter()
        .find(|t| t.name == "wire-1")
        .expect("the handshake registered the tenant");
    assert_eq!(t.accepted, 5, "3 kinds + big + cancelled");

    // SHUTDOWN stops the accept loop; wait() then joins every
    // connection thread.
    c.shutdown_server().unwrap();
    server.wait();
    drop(c);

    // The ledger balances once the service drains.
    let ledger = svc.client("wire-1");
    Arc::into_inner(svc).expect("server released its handle").shutdown();
    let t = ledger.tenant_metrics();
    assert_eq!(t.accepted, t.completed + t.cancelled + t.failed, "identity");
    assert_eq!(t.in_flight_bytes, 0, "no residual in-flight cost");
    assert_eq!(t.queued_jobs, 0);
}

#[test]
fn saturated_queue_maps_busy_to_retry_after() {
    // 0 workers → nothing drains → the 4-slot queue fills exactly,
    // and the wire must surface the coordinator's own Busy shed —
    // reason and hint — instead of dropping the connection.
    let cfg = CoordinatorConfig {
        workers: 0,
        shards: 1,
        queue_capacity: 4,
        ..Default::default()
    };
    let (svc, server) = serve(cfg);
    let mut c = WireClient::connect(server.local_addr()).unwrap();
    c.hello("sat", 1, 1 << 20).unwrap();

    let mut accepted = 0;
    let mut shed = None;
    for _ in 0..10 {
        match c.submit(ElemBuf::U32(vec![3, 1, 2])).unwrap() {
            SubmitOutcome::Accepted { .. } => accepted += 1,
            SubmitOutcome::RetryAfter { reason, hint } => {
                shed = Some((reason, hint));
                break;
            }
        }
    }
    assert_eq!(accepted, 4, "queue capacity is a hard bound over the wire too");
    let (reason, wire_hint) = shed.expect("the 5th submit must be shed");
    assert_eq!(reason, WireBusyReason::QueueFull, "under-burst tenant sheds as QueueFull");
    assert!(reason.retryable());

    // The same saturation observed in-process: the wire hint must be
    // byte-identical to the coordinator's own retry_after_hint (both
    // are the deterministic cold-start default — no completions, so
    // the p50 estimate is empty).
    let busy = svc.client("sat-local").try_submit(vec![9, 9]).expect_err("queue is full");
    assert!(matches!(busy.reason, BusyReason::QueueFull { .. }), "{:?}", busy.reason);
    let local_hint = busy.reason.retry_after().expect("QueueFull carries a hint");
    assert_eq!(wire_hint, local_hint, "RETRY_AFTER carries the in-process hint verbatim");

    // The connection survived the shed: metrics still answer.
    let m = c.metrics().unwrap();
    assert_eq!(m.net_retry_after, 1);
    assert_eq!(m.net_protocol_errors, 0);

    drop(c);
    server.stop();
    Arc::into_inner(svc).expect("server released its handle").shutdown();
}

#[test]
fn abrupt_disconnect_cancels_in_flight_work() {
    // Drop the TCP connection with submits still pending — no CANCEL
    // frames, no goodbye. The server must notice, drop the handles,
    // and let drop-to-cancel release every QoS charge.
    let cfg = CoordinatorConfig {
        workers: 0,
        shards: 1,
        queue_capacity: 64,
        ..Default::default()
    };
    let (svc, server) = serve(cfg);
    let mut c = WireClient::connect(server.local_addr()).unwrap();
    c.hello("vanish", 1, 1 << 20).unwrap();
    for _ in 0..3 {
        match c.submit(ElemBuf::U32(vec![5, 4, 3, 2, 1])).unwrap() {
            SubmitOutcome::Accepted { .. } => {}
            other => panic!("expected acceptance, got {other:?}"),
        }
    }
    drop(c); // abrupt: the socket just closes

    // The connection thread notices within its read timeout and tears
    // down, cancelling the three pending handles.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if svc.metrics().connections_open == 0 {
            break;
        }
        assert!(Instant::now() < deadline, "server never noticed the disconnect");
        std::thread::sleep(Duration::from_millis(5));
    }

    server.stop();
    let ledger = svc.client("vanish");
    Arc::into_inner(svc).expect("server released its handle").shutdown();
    let t = ledger.tenant_metrics();
    assert_eq!(t.accepted, 3);
    assert_eq!(t.cancelled, 3, "disconnect resolved every pending job as cancelled");
    assert_eq!(t.completed, 0, "no workers existed to complete anything");
    assert_eq!(t.accepted, t.completed + t.cancelled + t.failed, "identity");
    assert_eq!(t.in_flight_bytes, 0, "no leaked QoS charge");
    assert_eq!(t.queued_jobs, 0);
}

/// One soak connection: submit a payload mix with bounded
/// hint-honoring retries, cancel a stride of accepted ids over the
/// wire, drain the rest. Panics (failing the test) on any wire error.
fn soak_conn(addr: std::net::SocketAddr, tenant: usize, conn: usize, jobs: usize) {
    let mut rng = Rng::new(0x50AC ^ ((tenant as u64) << 8) ^ conn as u64);
    let mut c = WireClient::connect(addr).unwrap();
    c.hello(&format!("soak-{tenant}"), 1 + tenant as u32, 64 << 10).unwrap();
    let mut outstanding = Vec::new();
    for i in 0..jobs {
        let len = 16 + rng.below(600);
        let data = match (tenant + i) % 3 {
            0 => ElemBuf::U32(rng.vec_u32(len)),
            1 => ElemBuf::U64(rng.vec_u64(len)),
            _ => ElemBuf::Pair((0..len).map(|j| KeyValue::new(rng.next_u32(), j as u32)).collect()),
        };
        let mut attempts = 0;
        loop {
            attempts += 1;
            match c.submit(data.clone()).unwrap() {
                SubmitOutcome::Accepted { id } => {
                    if i % 13 == 7 {
                        c.cancel(id).unwrap();
                    } else {
                        outstanding.push(id);
                    }
                    break;
                }
                SubmitOutcome::RetryAfter { reason, hint } => {
                    if !reason.retryable() || attempts >= 6 {
                        break; // shed for good: never admitted, nothing to account
                    }
                    std::thread::sleep(hint.min(Duration::from_millis(2)));
                }
            }
        }
        // Poll opportunistically so the pending set stays small.
        if let Some(&id) = outstanding.first() {
            match c.poll(id).unwrap() {
                PollOutcome::Pending => {}
                PollOutcome::Done(out) => {
                    assert!(is_sorted(&out), "soak-{tenant}/{conn} got an unsorted result");
                    outstanding.remove(0);
                }
                PollOutcome::Failed(_) => {
                    outstanding.remove(0); // injected fault; accounted as failed
                }
            }
        }
    }
    for id in outstanding {
        if let Ok(out) = c.wait(id).unwrap() {
            assert!(is_sorted(&out), "soak-{tenant}/{conn} got an unsorted result");
        }
    }
    // Graceful close with nothing pending on this connection.
}

#[test]
fn soak_under_faults_across_the_wire() {
    // Multi-connection soak against a fault-injecting service:
    // contained sort panics, worker-killing panics, stalls, and
    // forced sheds — all while the wire layer must keep every
    // connection coherent and the per-tenant ledger exact.
    let plan = FaultPlan {
        seed: 0x5EED,
        sort_panic_per_mille: 80,
        fatal_panic_per_mille: 5,
        stall_per_mille: 30,
        stall: Duration::from_micros(200),
        shed_per_mille: 30,
        ..Default::default()
    };
    let cfg = CoordinatorConfig {
        workers: 2,
        shards: 2,
        batch_max: 8,
        queue_capacity: 16,
        faults: Some(plan),
        ..Default::default()
    };
    let (svc, server) = serve(cfg);
    let addr = server.local_addr();

    let joins: Vec<_> = (0..3)
        .flat_map(|t| (0..2).map(move |cx| (t, cx)))
        .map(|(t, cx)| std::thread::spawn(move || soak_conn(addr, t, cx, 60)))
        .collect();
    for j in joins {
        j.join().expect("a soak connection panicked");
    }

    // Quiesce: cancelled jobs may still occupy queue slots until a
    // worker skips them; wait for the gauges to drain, over the wire.
    let mut control = WireClient::connect(addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    let metrics = loop {
        let m = control.metrics().unwrap();
        let drained = m
            .tenants
            .iter()
            .filter(|t| t.name.starts_with("soak-"))
            .all(|t| t.in_flight_bytes == 0 && t.queued_jobs == 0);
        if drained {
            break m;
        }
        assert!(Instant::now() < deadline, "soak tenants never quiesced");
        std::thread::sleep(Duration::from_millis(5));
    };

    // The PR 8 identity, read across the wire, per tenant.
    let mut seen = 0;
    for t in metrics.tenants.iter().filter(|t| t.name.starts_with("soak-")) {
        seen += 1;
        assert_eq!(
            t.accepted,
            t.completed + t.cancelled + t.failed,
            "{}: accepted {} vs completed {} + cancelled {} + failed {}",
            t.name,
            t.accepted,
            t.completed,
            t.cancelled,
            t.failed
        );
        assert!(t.accepted > 0, "{}: the soak reached this tenant", t.name);
    }
    assert_eq!(seen, 3, "all three tenants registered over the wire");
    assert_eq!(metrics.net_protocol_errors, 0, "a clean client never desyncs the stream");
    assert!(metrics.quarantined <= metrics.failed, "quarantines surface as failures");

    drop(control);
    server.stop();
    Arc::into_inner(svc).expect("server released its handle").shutdown();
}
