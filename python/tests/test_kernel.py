"""L1 Pallas kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes, dtypes, and value distributions; explicit
cases pin the adversarial patterns the rust suite also uses.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import neon_ms, ref

DTYPES = [np.int32, np.float32, np.uint32]


def _assert_equal(got, want):
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dtype=st.sampled_from(DTYPES),
)
def test_tile_sort_matches_ref(tiles, seed, dtype):
    rng = np.random.RandomState(seed)
    n = tiles * neon_ms.TILE
    if dtype == np.float32:
        x = (rng.randn(n) * 1e3).astype(dtype)
    else:
        x = rng.randint(-(2**31), 2**31 - 1, size=n).astype(dtype)
    got = neon_ms.tile_sort(jnp.asarray(x))
    _assert_equal(got, ref.tile_sort_ref(jnp.asarray(x)))


@settings(max_examples=25, deadline=None)
@given(
    log_run=st.integers(min_value=2, max_value=8),
    pairs=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_merge_pass_matches_ref(log_run, pairs, seed):
    rng = np.random.RandomState(seed)
    run = 1 << log_run
    n = 2 * run * pairs
    x = rng.randint(0, 10**6, size=n).astype(np.int32)
    # Pre-sort each run (merge_pass contract).
    x = x.reshape(-1, run)
    x.sort(axis=1)
    x = x.reshape(n)
    got = neon_ms.merge_pass(jnp.asarray(x), run)
    _assert_equal(got, ref.merge_pass_ref(jnp.asarray(x), run))


@pytest.mark.parametrize("pattern", ["presorted", "reverse", "constant", "dups"])
def test_tile_sort_adversarial(pattern):
    n = 4 * neon_ms.TILE
    base = {
        "presorted": np.arange(n),
        "reverse": np.arange(n)[::-1],
        "constant": np.full(n, 7),
        "dups": np.arange(n) % 3,
    }[pattern].astype(np.int32)
    got = neon_ms.tile_sort(jnp.asarray(base))
    _assert_equal(got, ref.tile_sort_ref(jnp.asarray(base)))


def test_tile_sort_extreme_values():
    x = np.array(
        [2**31 - 1, -(2**31), 0, -1] * 16, dtype=np.int32
    )
    got = np.asarray(neon_ms.tile_sort(jnp.asarray(x)))
    want = np.sort(x.reshape(1, 64), axis=1).reshape(-1)
    np.testing.assert_array_equal(got, want)


def test_tile_sort_is_permutation():
    rng = np.random.RandomState(3)
    x = rng.randint(0, 50, size=neon_ms.TILE * 3).astype(np.int32)
    got = np.asarray(neon_ms.tile_sort(jnp.asarray(x)))
    assert sorted(got.tolist()) == sorted(x.tolist())


def test_tile_sort_odd_even_network_variant():
    rng = np.random.RandomState(4)
    x = rng.randint(-100, 100, size=neon_ms.TILE * 2).astype(np.int32)
    got = neon_ms.tile_sort(jnp.asarray(x), network="odd_even")
    _assert_equal(got, ref.tile_sort_ref(jnp.asarray(x)))


def test_tile_sort_rejects_misaligned():
    with pytest.raises(AssertionError):
        neon_ms.tile_sort(jnp.zeros(63, jnp.int32))
