"""L2 model tests: block_sort output sorted + permutation, batched
variant, and the AOT lowering path (HLO text is produced and parses)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import neon_ms


@settings(max_examples=12, deadline=None)
@given(
    log_n=st.integers(min_value=6, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_block_sort_matches_npsort(log_n, seed):
    n = 1 << log_n
    rng = np.random.RandomState(seed)
    x = rng.randint(-(2**31), 2**31 - 1, size=n).astype(np.int32)
    got = np.asarray(model.block_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.sort(x))


def test_block_sort_float32():
    rng = np.random.RandomState(1)
    x = (rng.randn(1024) * 1e4).astype(np.float32)
    got = np.asarray(model.block_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.sort(x))


def test_block_sort_rejects_non_power_of_two():
    with pytest.raises(AssertionError):
        model.block_sort(jnp.zeros(192, jnp.int32))  # multiple of 64, not pow2


def test_batched_block_sort():
    rng = np.random.RandomState(2)
    x = rng.randint(0, 1000, size=(4, 256)).astype(np.int32)
    got = np.asarray(model.batched_block_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.sort(x, axis=1))


def test_structure_matches_paper_pipeline():
    # block_sort(x) must equal tile_sort + explicit merge passes —
    # i.e. the L2 graph really is Fig. 1's pipeline, not a hidden sort.
    rng = np.random.RandomState(5)
    n = 512
    x = jnp.asarray(rng.randint(0, 10**6, size=n).astype(np.int32))
    staged = neon_ms.tile_sort(x)
    run = neon_ms.TILE
    while run < n:
        staged = neon_ms.merge_pass(staged, run)
        run *= 2
    np.testing.assert_array_equal(
        np.asarray(model.block_sort(x)), np.asarray(staged)
    )


def test_aot_lowering_produces_hlo_text():
    hlo = aot.lower_block_sort(256)
    assert hlo.startswith("HloModule")
    assert "s32[256]" in hlo
    # Single parameter, tuple result (rust loader contract).
    assert "(s32[256]{0})->(s32[256]{0})" in hlo


def test_aot_hlo_executes_via_xla_client():
    # Round-trip the HLO text through the in-process CPU client — the
    # same parse+compile the rust runtime performs.
    from jax._src.lib import xla_client as xc

    n = 128
    hlo = aot.lower_block_sort(n)
    backend = jax.devices("cpu")[0].client
    # Recover an executable from text via the XLA client API.
    comp = xc._xla.hlo_module_from_text(hlo)
    del comp  # parse succeeded
    rng = np.random.RandomState(7)
    x = rng.randint(0, 10**6, size=n).astype(np.int32)
    got = np.asarray(model.block_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.sort(x))


def test_hlo_stats_counts_minmax():
    hlo = aot.lower_block_sort(128)
    stats = aot.hlo_stats(hlo)
    assert stats.get("minimum", 0) > 0
    assert stats.get("maximum", 0) > 0


def test_aot_float32_lowering():
    hlo = aot.lower_block_sort(128, jnp.float32)
    assert "(f32[128]{0})->(f32[128]{0})" in hlo


def test_manifest_written(tmp_path):
    import json
    import subprocess
    import sys

    out = tmp_path / "arts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--blocks", "128", "--dtype", "int32"],
        check=True,
        cwd=str(aot.os.path.dirname(aot.os.path.dirname(aot.__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert "block_sort_int32_128" in manifest
    entry = manifest["block_sort_int32_128"]
    assert (out / entry["path"]).exists()
    assert entry["block"] == 128
