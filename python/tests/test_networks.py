"""Zero-one-principle validation of the network tables (the Python
twins of rust/src/sortnet — each side cross-checks the other)."""

import pytest

from compile.kernels import networks


def test_best16_is_greens_60():
    assert len(networks.BEST_16) == 60


def test_table1_comparator_counts():
    # Paper Table 1.
    assert len(networks.bitonic_sort(4)) == 6
    assert len(networks.bitonic_sort(8)) == 24
    assert len(networks.bitonic_sort(16)) == 80
    assert len(networks.bitonic_sort(32)) == 240
    assert len(networks.odd_even_sort(4)) == 5
    assert len(networks.odd_even_sort(8)) == 19
    assert len(networks.odd_even_sort(16)) == 63
    assert len(networks.odd_even_sort(32)) == 191
    assert len(networks.best(4)) == 5
    assert len(networks.best(8)) == 19
    assert 55 <= len(networks.best(16)) <= 60


@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_sorters_zero_one(n):
    assert networks.verify_zero_one(networks.bitonic_sort(n), n)
    assert networks.verify_zero_one(networks.odd_even_sort(n), n)
    assert networks.verify_zero_one(networks.best(n), n)


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 64])
def test_bitonic_merge_networks(n):
    comps = networks.bitonic_merge(n)
    lg = n.bit_length() - 1
    assert len(comps) == lg * n // 2
    assert networks.verify_bitonic_merge(comps, n)


def test_comparators_in_range():
    for comps, n in [
        (networks.BEST_16, 16),
        (networks.BEST_8, 8),
        (networks.BEST_4, 4),
    ]:
        assert all(0 <= i < n and 0 <= j < n and i != j for i, j in comps)
