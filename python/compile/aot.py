"""AOT compile path: lower the L2 block-sort to HLO **text** artifacts
the rust runtime loads via `HloModuleProto::from_text_file`.

Text, not `.serialize()`: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids which the published `xla` crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the HLO text parser reassigns ids,
so text round-trips cleanly (see /opt/xla-example/README.md).

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``make artifacts``). Python runs exactly once per source change; the
rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe route)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_block_sort(n: int, dtype=jnp.int32) -> str:
    fn, args = model.sort_fn_for_export(n, dtype)
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_batched_block_sort(batch: int, n: int, dtype=jnp.int32) -> str:
    fn, args = model.batched_sort_fn_for_export(batch, n, dtype)
    return to_hlo_text(jax.jit(fn).lower(*args))


def hlo_stats(hlo: str) -> dict:
    """Crude cost stats for DESIGN.md §Perf: op-class counts."""
    counts: dict = {}
    for line in hlo.splitlines():
        line = line.strip()
        if "=" not in line or line.startswith(("HloModule", "ENTRY", "}")):
            continue
        rhs = line.split("=", 1)[1].strip()
        if " " in rhs:
            op = rhs.split(" ", 1)[1].split("(", 1)[0].strip()
            for key in ("minimum", "maximum", "reverse", "concatenate",
                        "reshape", "fusion", "copy", "slice"):
                if op.startswith(key):
                    counts[key] = counts.get(key, 0) + 1
    return counts


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument(
        "--blocks", type=int, nargs="*", default=list(model.BLOCK_VARIANTS)
    )
    p.add_argument(
        "--dtype", default="both", choices=["int32", "float32", "both"]
    )
    p.add_argument("--stats", action="store_true", help="print HLO op stats")
    p.add_argument(
        "--batch", type=int, default=8,
        help="also emit a batched int32 variant (batch × smallest block); 0 disables",
    )
    args = p.parse_args()

    dtypes = (
        [("int32", jnp.int32), ("float32", jnp.float32)]
        if args.dtype == "both"
        else [(args.dtype, jnp.int32 if args.dtype == "int32" else jnp.float32)]
    )
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}
    for dname, dtype in dtypes:
      for n in args.blocks:
        t0 = time.time()
        hlo = lower_block_sort(n, dtype)
        name = f"block_sort_{dname}_{n}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        digest = hashlib.sha256(hlo.encode()).hexdigest()[:16]
        manifest[name] = {
            "path": os.path.basename(path),
            "block": n,
            "dtype": dname,
            "sha256_16": digest,
            "bytes": len(hlo),
        }
        msg = f"lowered {name}: {len(hlo)} chars in {time.time()-t0:.1f}s"
        print(msg, file=sys.stderr)
        if args.stats:
            print(json.dumps({name: hlo_stats(hlo)}, indent=2))
    if args.batch and any(d == "int32" for d, _ in dtypes):
        n = min(args.blocks)
        t0 = time.time()
        hlo = lower_batched_block_sort(args.batch, n)
        name = f"block_sort_batch{args.batch}_int32_{n}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        manifest[name] = {
            "path": os.path.basename(path),
            "block": n,
            "batch": args.batch,
            "dtype": "int32",
            "sha256_16": hashlib.sha256(hlo.encode()).hexdigest()[:16],
            "bytes": len(hlo),
        }
        print(
            f"lowered {name}: {len(hlo)} chars in {time.time()-t0:.1f}s",
            file=sys.stderr,
        )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
