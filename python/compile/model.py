"""Layer-2 JAX model: the full NEON-MS block sort as one jittable
compute graph — Pallas tile sort (L1) followed by log2(B/64) Pallas
merge passes, mirroring the rust sort's structure exactly.

This is the computation that `aot.py` lowers to HLO text; the rust
coordinator executes the compiled artifact on fixed-size blocks and
merges across blocks with its own (hybrid-merger) passes. Python is
never on the request path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import neon_ms

#: Block sizes we AOT-compile artifacts for (coordinator picks by size).
BLOCK_VARIANTS = (1024, 4096, 16384)


@functools.partial(jax.jit, static_argnames=("network",))
def block_sort(x, network: str = "best"):
    """Fully sort a 1-D block whose length is a power-of-two multiple
    of 64. Structure = paper Fig. 1: in-register (tile) sort, then
    doubling vectorized merge passes.
    """
    n = x.shape[0]
    assert n % neon_ms.TILE == 0 and (n & (n - 1)) == 0, (
        f"block length {n} must be a power of two ≥ {neon_ms.TILE}"
    )
    x = neon_ms.tile_sort(x, network=network)
    run = neon_ms.TILE
    while run < n:
        x = neon_ms.merge_pass(x, run)
        run *= 2
    return x


@functools.partial(jax.jit, static_argnames=("network",))
def batched_block_sort(x, network: str = "best"):
    """Sort each row of a (batch, block) array — the coordinator's
    batched path amortizes executable dispatch over several requests.
    """
    return jax.vmap(lambda row: block_sort(row, network=network))(x)


def sort_fn_for_export(n: int, dtype=jnp.int32):
    """(fn, example_args) pair for `aot.py` — returns a 1-tuple result
    as the rust loader expects (`to_tuple1`)."""

    def fn(x):
        return (block_sort(x),)

    return fn, (jax.ShapeDtypeStruct((n,), dtype),)


def batched_sort_fn_for_export(batch: int, n: int, dtype=jnp.int32):
    """Batched variant: `s32[batch, n] -> (s32[batch, n],)` — lets the
    rust coordinator amortize one PJRT dispatch over several queued
    requests (dynamic batching through the accelerator)."""

    def fn(x):
        return (batched_block_sort(x),)

    return fn, (jax.ShapeDtypeStruct((batch, n), dtype),)
