"""Pure-jnp correctness oracles for the Pallas kernels.

These never go through Pallas — they are the reference the kernel is
``assert_allclose``'d against in ``python/tests``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def sort_ref(x):
    """Full ascending sort."""
    return jnp.sort(x)


def tile_sort_ref(x, tile: int = 64):
    """Sort each aligned ``tile``-element chunk independently."""
    n = x.shape[0]
    assert n % tile == 0
    return jnp.sort(x.reshape(n // tile, tile), axis=1).reshape(n)


def merge_pass_ref(x, run: int):
    """Merge adjacent sorted runs of length ``run`` (oracle: just sort
    each 2·run window — inputs are pre-sorted halves so this equals the
    true merge)."""
    n = x.shape[0]
    assert n % (2 * run) == 0
    return jnp.sort(x.reshape(n // (2 * run), 2 * run), axis=1).reshape(n)


def np_block_sort_ref(x: np.ndarray) -> np.ndarray:
    """NumPy block-sort oracle for the AOT artifact tests."""
    return np.sort(x)
