"""Layer-1 Pallas kernels: the in-register sort and bitonic merge pass.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's NEON
register file becomes a VMEM tile. One grid program owns one 64-element
tile — the paper's R=16 × W=4 register block — and performs column
sort / transpose / row merge entirely on values resident in the tile,
exactly as the NEON version keeps them in registers. The HBM↔VMEM
schedule that NEON expressed with `vld1q` bursts is expressed here with
a `BlockSpec`; comparators become lane-wise `jnp.minimum/maximum` pairs
(pure VPU work, no MXU involvement).

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and correctness (not wallclock) is
what the interpret path validates. TPU performance is *estimated* in
DESIGN.md §Perf from VMEM footprint and op counts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import networks

# The paper's geometry: R = 16 vector registers × W = 4 lanes.
R = 16
W = 4
TILE = R * W  # 64 — sorted-run length produced by the tile sort


def _column_sort(x, comps):
    """Apply a sorting network across rows of an (R, W) tile.

    Comparator (i, j) performs a lane-wise min/max of rows i and j —
    one vmin + one vmax, all W columns at once (paper §2.3).
    """
    rows = [x[i] for i in range(x.shape[0])]
    for i, j in comps:
        lo = jnp.minimum(rows[i], rows[j])
        hi = jnp.maximum(rows[i], rows[j])
        rows[i], rows[j] = lo, hi
    return jnp.stack(rows)


def _bitonic_merge_flat(v):
    """Sort a bitonic vector (length power of two) ascending.

    The half-cleaner cascade, fully vectorized: at distance d the
    vector reshapes to (n/2d, 2, d) and one min/max pair handles the
    whole stage — the Pallas analogue of the register-level cmpswap
    stages plus the intra-register shuffles.
    """
    n = v.shape[0]
    d = n // 2
    while d >= 1:
        y = v.reshape(n // (2 * d), 2, d)
        lo = jnp.minimum(y[:, 0, :], y[:, 1, :])
        hi = jnp.maximum(y[:, 0, :], y[:, 1, :])
        v = jnp.stack([lo, hi], axis=1).reshape(n)
        d //= 2
    return v


def _merge_sorted_halves(v):
    """Merge a vector whose two halves are each sorted ascending."""
    n = v.shape[0]
    half = n // 2
    bitonic = jnp.concatenate([v[:half], v[half:][::-1]])
    return _bitonic_merge_flat(bitonic)


def _tile_sort_kernel(x_ref, o_ref, *, comps):
    """Sort one 64-element tile: the paper's in-register sort."""
    flat = x_ref[...]
    # 1. "load": view as the R×W register block.
    tile = flat.reshape(R, W)
    # 2. column sort (best-16 network, 60 comparators).
    tile = _column_sort(tile, comps)
    # 3. transpose → 4 sorted runs of 16, contiguous.
    runs = tile.T.reshape(TILE)
    # 4. row merge: 16 → 32 → 64, all in-tile.
    lo = _merge_sorted_halves(runs[: TILE // 2])
    hi = _merge_sorted_halves(runs[TILE // 2 :])
    o_ref[...] = _merge_sorted_halves(jnp.concatenate([lo, hi]))


@functools.partial(jax.jit, static_argnames=("network",))
def tile_sort(x, network: str = "best"):
    """Pallas tile sort: every aligned 64-element chunk of ``x`` comes
    back sorted. ``x.shape[0]`` must be a multiple of 64.
    """
    n = x.shape[0]
    assert n % TILE == 0, f"length {n} not a multiple of {TILE}"
    comps = networks.best(R) if network == "best" else networks.odd_even_sort(R)
    kernel = functools.partial(_tile_sort_kernel, comps=tuple(comps))
    return pl.pallas_call(
        kernel,
        grid=(n // TILE,),
        in_specs=[pl.BlockSpec((TILE,), lambda i: (i,))],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)


def _merge_pass_kernel(x_ref, o_ref):
    """Merge one adjacent pair of sorted runs (the tile's block is the
    pair; each half is sorted on entry)."""
    o_ref[...] = _merge_sorted_halves(x_ref[...])


@functools.partial(jax.jit, static_argnames=("run",))
def merge_pass(x, run: int):
    """One vectorized merge pass: adjacent sorted runs of length
    ``run`` merge into runs of ``2·run``. ``x.shape[0]`` must be a
    multiple of ``2·run``.
    """
    n = x.shape[0]
    assert n % (2 * run) == 0, f"length {n} not a multiple of {2 * run}"
    return pl.pallas_call(
        _merge_pass_kernel,
        grid=(n // (2 * run),),
        in_specs=[pl.BlockSpec((2 * run,), lambda i: (i,))],
        out_specs=pl.BlockSpec((2 * run,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)
