"""Sorting-network comparator tables (paper §2.3, Table 1).

Python twin of ``rust/src/sortnet`` — the same three families the paper
compares, used by the Pallas kernel (column sort) and cross-checked by
the zero-one principle in ``python/tests/test_networks.py``. Keeping an
independent copy (rather than generating one from the other) lets each
side's test suite validate the other's tables.
"""

from __future__ import annotations

from functools import lru_cache
from typing import List, Tuple

Comparator = Tuple[int, int]

# Green's 60-comparator, depth-10 best-known network for 16 inputs —
# the paper's "best 16-element sorting network" (the 16* rows).
BEST_16: List[Comparator] = [
    # layer 1
    (0, 1), (2, 3), (4, 5), (6, 7), (8, 9), (10, 11), (12, 13), (14, 15),
    # layer 2
    (0, 2), (4, 6), (8, 10), (12, 14), (1, 3), (5, 7), (9, 11), (13, 15),
    # layer 3
    (0, 4), (8, 12), (1, 5), (9, 13), (2, 6), (10, 14), (3, 7), (11, 15),
    # layer 4
    (0, 8), (1, 9), (2, 10), (3, 11), (4, 12), (5, 13), (6, 14), (7, 15),
    # layer 5
    (5, 10), (6, 9), (3, 12), (13, 14), (7, 11), (1, 2), (4, 8),
    # layer 6
    (1, 4), (7, 13), (2, 8), (11, 14), (5, 6), (9, 10),
    # layer 7
    (2, 4), (11, 13), (3, 8), (7, 12),
    # layer 8
    (6, 8), (10, 12), (3, 5), (7, 9),
    # layer 9
    (3, 4), (5, 6), (7, 8), (9, 10), (11, 12),
    # layer 10
    (6, 7), (8, 9),
]

# Optimal small networks (Knuth TAOCP §5.3.4).
BEST_4: List[Comparator] = [(0, 1), (2, 3), (0, 2), (1, 3), (1, 2)]
BEST_8: List[Comparator] = [
    (0, 1), (2, 3), (4, 5), (6, 7),
    (0, 2), (1, 3), (4, 6), (5, 7),
    (1, 2), (5, 6), (0, 4), (3, 7),
    (1, 5), (2, 6), (1, 4), (3, 6),
    (2, 4), (3, 5), (3, 4),
]


@lru_cache(maxsize=None)
def bitonic_sort(n: int) -> Tuple[Comparator, ...]:
    """Full bitonic sorter (directional comparators), n a power of two."""
    assert n & (n - 1) == 0 and n > 0
    comps: List[Comparator] = []
    k = 2
    while k <= n:
        j = k // 2
        while j > 0:
            for i in range(n):
                l = i ^ j
                if l > i:
                    comps.append((i, l) if i & k == 0 else (l, i))
            j //= 2
        k *= 2
    return tuple(comps)


@lru_cache(maxsize=None)
def odd_even_sort(n: int) -> Tuple[Comparator, ...]:
    """Batcher odd-even mergesort network, n a power of two."""
    assert n & (n - 1) == 0 and n > 0
    comps: List[Comparator] = []

    def merge(lo: int, length: int, r: int) -> None:
        m = r * 2
        if m < length:
            merge(lo, length, m)
            merge(lo + r, length, m)
            for i in range(lo + r, lo + length - r, m):
                comps.append((i, i + r))
        else:
            comps.append((lo, lo + r))

    def sort(lo: int, length: int) -> None:
        if length > 1:
            m = length // 2
            sort(lo, m)
            sort(lo + m, m)
            merge(lo, length, 1)

    sort(0, n)
    return tuple(comps)


@lru_cache(maxsize=None)
def bitonic_merge(n: int) -> Tuple[Comparator, ...]:
    """Half-cleaner cascade sorting any bitonic input of length n."""
    assert n & (n - 1) == 0 and n > 0
    comps: List[Comparator] = []
    j = n // 2
    while j > 0:
        for i in range(n):
            if i % (2 * j) < j:
                comps.append((i, i + j))
        j //= 2
    return tuple(comps)


def best(n: int) -> Tuple[Comparator, ...]:
    """Best-known network for the sizes the kernel uses."""
    if n == 4:
        return tuple(BEST_4)
    if n == 8:
        return tuple(BEST_8)
    if n == 16:
        return tuple(BEST_16)
    return odd_even_sort(n)


def verify_zero_one(comps, n: int) -> bool:
    """Exhaustive zero-one-principle check (n ≤ 24)."""
    assert n <= 24
    for pattern in range(1 << n):
        v = [(pattern >> b) & 1 for b in range(n)]
        for i, j in comps:
            if v[i] > v[j]:
                v[i], v[j] = v[j], v[i]
        if any(v[k] > v[k + 1] for k in range(n - 1)):
            return False
    return True


def verify_bitonic_merge(comps, n: int) -> bool:
    """Check the network sorts every asc⌢desc zero-one input."""
    for start in range(n + 1):
        for end in range(start, n + 1):
            v = [1 if start <= b < end else 0 for b in range(n)]
            for i, j in comps:
                if v[i] > v[j]:
                    v[i], v[j] = v[j], v[i]
            if any(v[k] > v[k + 1] for k in range(n - 1)):
                return False
    return True
